"""Schedule checks (repro.check, component 2).

A :class:`repro.core.scheduler.Schedule` is only executable when

* every graph op is assigned to exactly one CompNode,
* each stage's compute ops form a contiguous run of :func:`chain` order
  and the runs appear in pipeline order (the GPipe executor and every
  Table-3 edge-set derivation assume it),
* the stage list is consistent (unique, in range, covering every
  non-empty CompNode) and every stage host is a member of the allowed
  device subset (the elastic runtime must never schedule onto the dead),
* each stage host can actually hold its shard: parameters + optimizer
  state + one micro-batch of activations within ``DeviceSpec.mem_bytes``.

:func:`verify_schedule` raises :class:`ScheduleCheckError` naming the
offending op/device.  The planners call it on every schedule they emit
(``verify=False`` opts out).
"""
from __future__ import annotations

from typing import List, Mapping, Optional, Sequence

from repro.core.estimator import ClusterSpec
from repro.core.opgraph import OpGraph, OpProfile
from repro.core.opgraph import chain as op_chain

from .errors import Finding, ScheduleCheckError, raise_findings


def _coverage_findings(graph: OpGraph, schedule) -> List[Finding]:
    out: List[Finding] = []
    owner: dict = {}
    for dev, seg in enumerate(schedule.assignment):
        for op in seg:
            if op not in graph.nodes:
                out.append(Finding("unknown-op", op,
                                   f"CompNode {dev} holds op {op!r} absent "
                                   "from the graph"))
            if op in owner:
                out.append(Finding(
                    "double-assignment", op,
                    f"op {op!r} assigned to CompNodes {owner[op]} and "
                    f"{dev}"))
            owner[op] = dev
    for op in graph.nodes:
        if op not in owner:
            out.append(Finding("unassigned-op", op,
                               f"op {op!r} is assigned to no CompNode"))
    return out


def _stage_findings(graph: OpGraph, schedule,
                    cluster: Optional[ClusterSpec],
                    alive: Optional[Sequence[int]]) -> List[Finding]:
    out: List[Finding] = []
    n_dev = len(schedule.assignment)
    seen: set = set()
    for d in schedule.stages:
        if not 0 <= d < n_dev:
            out.append(Finding("stage-out-of-range", f"dev{d}",
                               f"stage device {d} outside the {n_dev}-wide "
                               "assignment"))
            continue
        if d in seen:
            out.append(Finding("duplicate-stage", f"dev{d}",
                               f"device {d} listed twice in stages"))
        seen.add(d)
    for d, seg in enumerate(schedule.assignment):
        if seg and d not in seen:
            out.append(Finding(
                "stage-missing-device", f"dev{d}",
                f"CompNode {d} holds {seg[0]!r} (+{len(seg) - 1} more) but "
                "is absent from the stage order"))
    if cluster is not None and n_dev != len(cluster):
        out.append(Finding(
            "assignment-size", "<schedule>",
            f"assignment spans {n_dev} CompNodes but the cluster has "
            f"{len(cluster)}"))
    if alive is not None:
        alive_set = {int(a) for a in alive}
        for d in schedule.stage_devices():
            if d not in alive_set:
                seg = schedule.assignment[d]
                out.append(Finding(
                    "dead-device", f"dev{d}",
                    f"stage host {d} is outside the allowed subset "
                    f"(holds {seg[0]!r} (+{len(seg) - 1} more))"))
    if cluster is not None:
        hosts = [d for d in schedule.stage_devices() if 0 <= d < len(cluster)]
        for s, d in zip(hosts, hosts[1:]):
            try:
                cluster.link(s, d)
            except KeyError:
                out.append(Finding(
                    "missing-link", f"dev{s}->dev{d}",
                    f"consecutive stages on CompNodes {s} and {d} share no "
                    "link in the cluster spec"))
    return out


def _contiguity_findings(graph: OpGraph, schedule) -> List[Finding]:
    """Each stage's compute ops must be one contiguous chain() run, and the
    runs must appear in pipeline order covering the whole chain."""
    order = op_chain(graph)
    pos = {op: i for i, op in enumerate(order)}
    out: List[Finding] = []
    cursor = 0
    for d in schedule.stage_devices():
        idxs = sorted(pos[op] for op in schedule.assignment[d] if op in pos)
        if not idxs:
            continue
        lo, hi = idxs[0], idxs[-1]
        if idxs != list(range(lo, hi + 1)):
            gap = next(i for a, b in zip(idxs, idxs[1:])
                       for i in (a + 1,) if b != a + 1)
            out.append(Finding(
                "non-contiguous-stage", order[gap],
                f"CompNode {d} holds a chain gap: op {order[gap]!r} "
                f"(chain #{gap}) belongs to its [{order[lo]!r}..."
                f"{order[hi]!r}] run but lives elsewhere"))
            cursor = hi + 1
            continue
        if lo != cursor:
            out.append(Finding(
                "stage-order", order[lo],
                f"CompNode {d} starts at chain #{lo} ({order[lo]!r}) but "
                f"the pipeline cursor is at #{cursor} "
                f"({order[cursor]!r} misplaced)" if cursor < len(order)
                else f"CompNode {d} starts past the end of the chain"))
        cursor = max(cursor, hi + 1)
    return out


def _capacity_findings(graph: OpGraph, schedule,
                       profiles: Mapping[str, OpProfile],
                       cluster: ClusterSpec,
                       opt_state_mult: float,
                       mem_margin: float) -> List[Finding]:
    out: List[Finding] = []
    for d in schedule.stage_devices():
        if not 0 <= d < len(cluster):
            continue
        need = 0.0
        biggest, biggest_op = 0.0, ""
        for op in schedule.assignment[d]:
            p = profiles.get(op)
            if p is None:
                continue
            cost = p.param_bytes * (1.0 + opt_state_mult) + p.out_bytes
            need += cost
            if cost > biggest:
                biggest, biggest_op = cost, op
        cap = cluster.devices[d].mem_bytes * mem_margin
        if need > cap:
            out.append(Finding(
                "capacity", biggest_op or f"dev{d}",
                f"CompNode {d} ({cluster.devices[d].name}) needs "
                f"{need / 1e9:.2f} GB (params x(1+{opt_state_mult:g}) + "
                f"activations; largest op {biggest_op!r} at "
                f"{biggest / 1e9:.2f} GB) but holds {cap / 1e9:.2f} GB"))
    return out


def check_schedule(graph: OpGraph, schedule,
                   profiles: Optional[Mapping[str, OpProfile]] = None,
                   cluster: Optional[ClusterSpec] = None,
                   alive: Optional[Sequence[int]] = None,
                   opt_state_mult: float = 2.0,
                   mem_margin: float = 1.0,
                   check_capacity: bool = True) -> List[Finding]:
    findings = _coverage_findings(graph, schedule)
    findings += _stage_findings(graph, schedule, cluster, alive)
    if not any(f.code in ("double-assignment", "unknown-op")
               for f in findings):
        findings += _contiguity_findings(graph, schedule)
    if check_capacity and profiles is not None and cluster is not None \
            and len(schedule.assignment) == len(cluster):
        findings += _capacity_findings(graph, schedule, profiles, cluster,
                                       opt_state_mult, mem_margin)
    return findings


def verify_schedule(graph: OpGraph, schedule,
                    profiles: Optional[Mapping[str, OpProfile]] = None,
                    cluster: Optional[ClusterSpec] = None,
                    alive: Optional[Sequence[int]] = None,
                    opt_state_mult: float = 2.0,
                    mem_margin: float = 1.0,
                    check_capacity: bool = True,
                    strict: bool = False) -> List[Finding]:
    """Raise :class:`ScheduleCheckError` on any error-severity finding;
    returns the findings otherwise."""
    findings = check_schedule(graph, schedule, profiles=profiles,
                              cluster=cluster, alive=alive,
                              opt_state_mult=opt_state_mult,
                              mem_margin=mem_margin,
                              check_capacity=check_capacity)
    return raise_findings(findings, ScheduleCheckError,
                          "schedule failed verification", strict=strict)
