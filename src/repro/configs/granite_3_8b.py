"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155, tied embeddings.  [hf:ibm-granite/granite-3.0-2b-base]"""
from .base import ArchEntry, ModelCfg, register

FULL = ModelCfg(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12800, vocab=49155, vocab_pad_to=256,
    norm="rmsnorm", act="silu", rope_theta=10_000.0,
    tie_embeddings=True, long_window=4096,
    source="hf:ibm-granite/granite-3.0-2b-base",
)

SMOKE = FULL.replace(
    name="granite-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    head_dim=32, d_ff=256, vocab=512, vocab_pad_to=1, max_seq=512)

register(ArchEntry(arch_id="granite-3-8b", full=FULL, smoke=SMOKE))
