"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, 8 experts top-2, sliding-window attention.  [arXiv:2401.04088]"""
from .base import ArchEntry, ModelCfg, register

FULL = ModelCfg(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000, vocab_pad_to=256,
    norm="rmsnorm", act="silu", rope_theta=1_000_000.0,
    n_experts=8, top_k=2, window=4096, long_window=4096,
    moe_impl="capacity",
    source="arXiv:2401.04088",
)

SMOKE = FULL.replace(
    name="mixtral-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    head_dim=32, d_ff=128, vocab=512, vocab_pad_to=1, n_experts=4, top_k=2,
    window=64, long_window=64, moe_impl="ragged", max_seq=512)

register(ArchEntry(arch_id="mixtral-8x7b", full=FULL, smoke=SMOKE))
