"""llama3-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256, GQA, 128k vocab.  [arXiv:2407.21783]"""
from .base import ArchEntry, ModelCfg, register

FULL = ModelCfg(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256, vocab_pad_to=256,
    norm="rmsnorm", act="silu", rope_theta=500_000.0,
    long_window=4096,   # long_500k runs the SWA variant (DESIGN.md §5)
    source="arXiv:2407.21783",
)

SMOKE = FULL.replace(
    name="llama3-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    head_dim=32, d_ff=256, vocab=512, vocab_pad_to=1, max_seq=512)

register(ArchEntry(arch_id="llama3-8b", full=FULL, smoke=SMOKE))
