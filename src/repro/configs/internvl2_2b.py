"""internvl2-2b [vlm] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternViT (stub frontend) + InternLM2 decoder.
[arXiv:2404.16821]

The ViT + MLP projector frontend is a STUB per the assignment carve-out:
``input_specs`` supplies 256 precomputed patch embeddings (d_frontend=1024,
InternViT-300M width after pixel-shuffle) which a learned linear projector
maps into the LM; text tokens follow."""
from .base import ArchEntry, ModelCfg, register

FULL = ModelCfg(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=92553, vocab_pad_to=256,
    norm="rmsnorm", act="silu", rope_theta=1_000_000.0,
    n_prefix=256, d_frontend=1024,
    long_window=4096,
    source="arXiv:2404.16821",
)

SMOKE = FULL.replace(
    name="internvl2-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    head_dim=32, d_ff=256, vocab=512, vocab_pad_to=1, n_prefix=8,
    d_frontend=64, max_seq=512)

register(ArchEntry(arch_id="internvl2-2b", full=FULL, smoke=SMOKE))
