"""Architecture configs.  ``load_all()`` imports every per-arch module so the
registry is populated; ``repro.configs.base.get_arch`` is the public lookup."""
from .base import (ArchEntry, InputShape, INPUT_SHAPES, ModelCfg, REGISTRY,
                   get_arch, register)

_LOADED = False

ARCH_IDS = [
    "zamba2-7b", "deepseek-moe-16b", "mistral-nemo-12b", "llama3-8b",
    "mixtral-8x7b", "stablelm-12b", "internvl2-2b", "seamless-m4t-large-v2",
    "granite-3-8b", "xlstm-1_3b", "gpt2-xl",
]

_MODULES = {
    "zamba2-7b": "zamba2_7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "llama3-8b": "llama3_8b",
    "mixtral-8x7b": "mixtral_8x7b",
    "stablelm-12b": "stablelm_12b",
    "internvl2-2b": "internvl2_2b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "granite-3-8b": "granite_3_8b",
    "xlstm-1_3b": "xlstm_1_3b",
    "gpt2-xl": "gpt2_xl",
}

# accepted aliases (the assignment writes xlstm-1.3b)
ALIASES = {"xlstm-1.3b": "xlstm-1_3b"}


def load_all() -> None:
    global _LOADED
    if _LOADED:
        return
    import importlib
    for mod in _MODULES.values():
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True


def resolve(arch_id: str) -> ArchEntry:
    load_all()
    arch_id = ALIASES.get(arch_id, arch_id)
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(REGISTRY)}")
    return REGISTRY[arch_id]
