"""Architecture configuration schema.

One :class:`ModelCfg` describes any architecture in the assigned pool
(dense / MoE / SSM / hybrid / xLSTM / enc-dec / VLM / audio).  Each config
module under ``repro/configs`` exports ``FULL`` (the exact assigned
architecture) and ``SMOKE`` (a reduced same-family variant: ≤2 layers,
d_model ≤ 512, ≤4 experts) plus registers itself in :data:`REGISTRY`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    family: str                     # dense | moe | hybrid | xlstm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0               # 0 -> d_model // n_heads
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "silu"               # silu | gelu
    rope_fraction: float = 1.0      # 0 -> learned positional embeddings
    rope_theta: float = 10_000.0
    max_seq: int = 8192             # only used for learned pos-emb sizing
    window: Optional[int] = None    # sliding-window attention (train/serve)
    long_window: Optional[int] = 4096  # SWA window substituted for long_500k
    tie_embeddings: bool = False
    qkv_bias: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_impl: str = "ragged"        # ragged | capacity | loop
    aux_loss_weight: float = 0.01
    capacity_factor: float = 1.25

    # --- SSM / hybrid (Mamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0             # hybrid: shared attn after every k SSM blocks
    n_shared_attn: int = 0          # alternating shared attention blocks

    # --- xLSTM ---
    slstm_every: int = 0            # one sLSTM per this many blocks (rest mLSTM)

    # --- enc-dec ---
    n_enc_layers: int = 0           # n_layers counts enc+dec when family=encdec

    # --- multimodal stubs ---
    n_prefix: int = 0               # patch/frame embeddings prepended
    d_frontend: int = 0             # stub frontend embedding width

    # --- numerics ---
    dtype: Any = jnp.float32        # activation dtype
    param_dtype: Any = jnp.float32
    vocab_pad_to: int = 1           # pad embedding/head vocab dim (sharding)
    remat: bool = False             # checkpoint each block (train memory)
    remat_policy: str = "nothing"   # nothing | dots (save matmul outputs)

    # provenance
    source: str = ""                # paper / model-card citation

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family in ("moe",) and (self.n_experts == 0 or self.top_k == 0):
            raise ValueError(f"{self.name}: moe family needs experts/top_k")
        if self.family == "hybrid" and self.attn_every == 0:
            raise ValueError(f"{self.name}: hybrid needs attn_every")
        if self.family == "encdec" and self.n_enc_layers == 0:
            raise ValueError(f"{self.name}: encdec needs n_enc_layers")

    @property
    def vocab_padded(self) -> int:
        p = max(self.vocab_pad_to, 1)
        return ((self.vocab + p - 1) // p) * p

    @property
    def n_dec_layers(self) -> int:
        return self.n_layers - self.n_enc_layers if self.family == "encdec" \
            else self.n_layers

    def replace(self, **kw) -> "ModelCfg":
        return dataclasses.replace(self, **kw)


# ----------------------------------------------------------------- shapes --
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


REGISTRY: Dict[str, "ArchEntry"] = {}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    full: ModelCfg
    smoke: ModelCfg
    # which input shapes apply (DESIGN.md §5 notes the skips)
    shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k",
                               "long_500k")
    skip_notes: str = ""


def register(entry: ArchEntry) -> ArchEntry:
    REGISTRY[entry.arch_id] = entry
    return entry


def get_arch(arch_id: str) -> ArchEntry:
    # import side-effect registration
    from repro import configs as _c  # noqa
    _c.load_all()
    return REGISTRY[arch_id]
