"""xlstm-1.3b [ssm] — 48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304 —
sLSTM + mLSTM blocks at the paper's 7:1 ratio (one sLSTM per 8 blocks);
d_ff=0 because FFN capacity lives inside the blocks (mLSTM pre-up-projection
×2, sLSTM gated FFN ×4/3).  [arXiv:2405.04517]"""
from .base import ArchEntry, ModelCfg, register

FULL = ModelCfg(
    name="xlstm-1_3b", family="xlstm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
    d_ff=0, vocab=50304, vocab_pad_to=256,
    norm="rmsnorm", act="gelu", rope_fraction=1.0,  # rope unused by blocks
    slstm_every=8,
    long_window=None,   # native O(1)-state recurrent decode
    source="arXiv:2405.04517",
)

SMOKE = FULL.replace(
    name="xlstm-smoke", n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
    head_dim=32, vocab=512, vocab_pad_to=1, slstm_every=2, max_seq=512)

register(ArchEntry(arch_id="xlstm-1_3b", full=FULL, smoke=SMOKE))
