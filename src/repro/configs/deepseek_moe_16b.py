"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64 experts top-6, 2 shared experts, fine-grained.
[arXiv:2401.06066]

Note: the reference model's first layer is a dense MLP; we keep all 28
layers MoE for uniform scan structure (bias < 2% of FLOPs, noted here for
fidelity accounting)."""
from .base import ArchEntry, ModelCfg, register

FULL = ModelCfg(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=102400, vocab_pad_to=256,
    norm="rmsnorm", act="silu", rope_theta=10_000.0,
    n_experts=64, top_k=6, n_shared_experts=2,
    long_window=4096, moe_impl="capacity",
    source="arXiv:2401.06066",
)

SMOKE = FULL.replace(
    name="deepseek-moe-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, head_dim=32, d_ff=64, vocab=512, vocab_pad_to=1,
    n_experts=4, top_k=2, n_shared_experts=1, moe_impl="ragged", max_seq=512)

register(ArchEntry(arch_id="deepseek-moe-16b", full=FULL, smoke=SMOKE))
