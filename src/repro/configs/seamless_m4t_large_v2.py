"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206 — encoder-decoder, multimodal.  [arXiv:2308.11596]

Interpretation (DESIGN.md §5): 24L = 12 encoder + 12 decoder transformer
layers.  The speech frontend (mel + w2v-BERT conv feature extractor) is a
STUB per the carve-out: ``input_specs`` supplies precomputed frame
embeddings (B, S_src, 1024).  long_500k is SKIPPED for this arch: an
enc-dec with a bounded source has no 500k-token decode regime."""
from .base import ArchEntry, ModelCfg, register

FULL = ModelCfg(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=8192, vocab=256206, vocab_pad_to=256,
    norm="layernorm", act="gelu", rope_theta=10_000.0,
    d_frontend=1024,
    source="arXiv:2308.11596",
)

SMOKE = FULL.replace(
    name="seamless-smoke", n_layers=2, n_enc_layers=1, d_model=128,
    n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256, vocab=512,
    vocab_pad_to=1, d_frontend=64, max_seq=512)

register(ArchEntry(
    arch_id="seamless-m4t-large-v2", full=FULL, smoke=SMOKE,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes="long_500k skipped: enc-dec with bounded source length "
               "(DESIGN.md §5)"))
