"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352.  Family traits per the StableLM-2 card: LayerNorm, partial
rotary (25%), qkv biases.  [hf:stabilityai/stablelm-2-1_6b]"""
from .base import ArchEntry, ModelCfg, register

FULL = ModelCfg(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=160,
    d_ff=13824, vocab=100352, vocab_pad_to=256,
    norm="layernorm", act="silu", rope_fraction=0.25,
    rope_theta=10_000.0, qkv_bias=True,
    long_window=4096,
    source="hf:stabilityai/stablelm-2-1_6b",
)

SMOKE = FULL.replace(
    name="stablelm-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    head_dim=32, d_ff=256, vocab=512, vocab_pad_to=1, max_seq=512)

register(ArchEntry(arch_id="stablelm-12b", full=FULL, smoke=SMOKE))
