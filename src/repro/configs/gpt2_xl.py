"""gpt2-xl — the paper's own workload (FusionLLM Table 6): 48L d_model=1600
25H d_ff=6400 vocab=50257, learned positional embeddings, LayerNorm + GELU.
[Radford et al. 2019]

Not part of the assigned 10×4 matrix; used by the paper-reproduction
benchmarks (Fig. 8/10/11) and the decentralized-runtime examples."""
from .base import ArchEntry, ModelCfg, register

FULL = ModelCfg(
    name="gpt2-xl", family="dense",
    n_layers=48, d_model=1600, n_heads=25, n_kv_heads=25, head_dim=64,
    d_ff=6400, vocab=50257, vocab_pad_to=256,
    norm="layernorm", act="gelu", rope_fraction=0.0, max_seq=1024,
    source="GPT-2 (Radford et al. 2019); FusionLLM Table 6",
)

SMOKE = FULL.replace(
    name="gpt2-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    head_dim=32, d_ff=256, vocab=512, vocab_pad_to=1, max_seq=512)

register(ArchEntry(arch_id="gpt2-xl", full=FULL, smoke=SMOKE,
                   shapes=("train_4k",),
                   skip_notes="paper workload, not in the assigned matrix; "
                              "max_seq=1024 (learned pos-emb)"))
