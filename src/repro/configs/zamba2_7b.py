"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention blocks
(2 alternating shared blocks, one invocation every 6 Mamba layers; the
assigned d_ff belongs to the shared block's MLP).  [arXiv:2411.15242]"""
from .base import ArchEntry, ModelCfg, register

FULL = ModelCfg(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000, vocab_pad_to=256,
    norm="rmsnorm", act="silu", rope_theta=10_000.0,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
    attn_every=6, n_shared_attn=2,
    long_window=None,    # SSM state is O(1); shared attn keeps full KV
    source="arXiv:2411.15242",
)

SMOKE = FULL.replace(
    name="zamba2-smoke", n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
    head_dim=32, d_ff=256, vocab=512, vocab_pad_to=1,
    ssm_state=16, ssm_head_dim=32, ssm_chunk=8, attn_every=2,
    n_shared_attn=2, max_seq=512)

register(ArchEntry(arch_id="zamba2-7b", full=FULL, smoke=SMOKE))
