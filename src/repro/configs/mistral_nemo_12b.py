"""mistral-nemo-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, 128k ctx (head_dim=128 fixed, not d_model/n_heads).
[hf:mistralai/Mistral-Nemo-Base-2407]"""
from .base import ArchEntry, ModelCfg, register

FULL = ModelCfg(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072, vocab_pad_to=256,
    norm="rmsnorm", act="silu", rope_theta=1_000_000.0,
    long_window=4096,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)

SMOKE = FULL.replace(
    name="mistral-nemo-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, head_dim=32, d_ff=256, vocab=512, vocab_pad_to=1,
    max_seq=512)

register(ArchEntry(arch_id="mistral-nemo-12b", full=FULL, smoke=SMOKE))
