from .checkpoint import (save_checkpoint, load_checkpoint, latest_checkpoint,
                         serialize_state, deserialize_state)
