"""Pytree checkpointing: flattened-path .npz + JSON metadata.

No orbax/tensorstore offline; numpy .npz with '/'-joined tree paths is
portable, append-free, and supports partial (per-CompNode) restore — which
the decentralized runtime uses so each participant checkpoints only its own
sub-DAG's parameters (paper §3.3 Update).
"""
from __future__ import annotations

import io
import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
            arr = arr.astype(np.float32)   # .npz-portable; cast back on load
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_checkpoint(directory: str, step: int, params: Any,
                    opt_state: Any = None,
                    metadata: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    payload = {f"params/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        payload.update({f"opt/{k}": v for k, v in _flatten(opt_state).items()})
    np.savez(path, **payload)
    meta = dict(metadata or {})
    meta["step"] = step
    with open(path.replace(".npz", ".json"), "w") as f:
        json.dump(meta, f, indent=2, default=str)
    return path


def _restore(data: Any, template: Any, prefix: str) -> Any:
    """Rebuild a pytree from flattened-path arrays (shape/dtype-checked)."""
    flat_t = _flatten(template)
    leaves, treedef = jax.tree_util.tree_flatten(template)
    keys = list(flat_t.keys())
    assert len(keys) == len(leaves)
    new = []
    for k, leaf in zip(keys, leaves):
        arr = data[f"{prefix}/{k}"]
        if arr.shape != tuple(np.shape(leaf)):
            raise ValueError(f"ckpt leaf {k}: shape {arr.shape} vs "
                             f"template {np.shape(leaf)}")
        # jnp handles ml_dtypes targets (bf16) that numpy cannot cast to
        new.append(jnp.asarray(arr).astype(jnp.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, new)


def load_checkpoint(path: str, params_template: Any,
                    opt_template: Any = None) -> Tuple[Any, Any, Dict]:
    """Restore into the structure of the provided templates (shape-checked)."""
    data = np.load(path)
    meta_path = path.replace(".npz", ".json")
    meta = json.load(open(meta_path)) if os.path.exists(meta_path) else {}
    params = _restore(data, params_template, "params")
    opt = _restore(data, opt_template, "opt") if opt_template is not None \
        else None
    return params, opt, meta


def serialize_state(params: Any, opt_state: Any = None) -> bytes:
    """Pack (params, opt_state) into .npz bytes — the same wire format as
    on-disk checkpoints, held in memory.  The elastic runtime ships migrated
    sub-trees between CompNodes in this envelope, so a migration exercises
    the identical flatten/cast path as a checkpoint round-trip (bit-exact,
    tested)."""
    payload = {f"params/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        payload.update({f"opt/{k}": v
                        for k, v in _flatten(opt_state).items()})
    buf = io.BytesIO()
    np.savez(buf, **payload)
    return buf.getvalue()


def deserialize_state(blob: bytes, params_template: Any,
                      opt_template: Any = None) -> Tuple[Any, Any]:
    """Inverse of :func:`serialize_state` (structure comes from templates)."""
    data = np.load(io.BytesIO(blob))
    params = _restore(data, params_template, "params")
    opt = _restore(data, opt_template, "opt") if opt_template is not None \
        else None
    return params, opt


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    best, best_step = None, -1
    for f in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d+)\.npz", f)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(directory, f), int(m.group(1))
    return best
