"""Jitted public wrappers over the Pallas Top-K kernels, plus the kernel
dispatch policy used by the compression hot path.

``topk_mask(x, k)`` matches :func:`repro.core.compression.topk_mask`'s
global-k signature by converting the global k into a per-block k (ceil
split).  Global and blockwise selections differ (documented: blockwise is
the standard approximation real compression kernels ship — it bounds the
worst-case block and parallelizes perfectly); convergence benchmarks compare
both (benchmarks/convergence.py).

Dispatch policy
---------------
Every ``use_kernel`` argument on the hot path (``compress_for_edge``,
``boundary_compress``, ``ef_compress``, ``topk_mask``) accepts a policy,
resolved here by :func:`resolve_policy` into an execution mode:

* ``False`` / ``None`` / ``"off"`` -> ``"global"`` — the legacy global
  top-k XLA formulation (bit-compatible with the historical default).
* ``"auto"`` -> ``"pallas"`` (compiled kernels) on a TPU backend, else
  ``"xla"`` — the fused blockwise oracle jitted under XLA, which has the
  *same* tie-capped selection semantics as the kernels, so numerics do not
  change when the job moves between CPU CI and TPU hardware.
* ``True`` / ``"force"`` -> the Pallas kernels even off-TPU
  (``"interpret"`` mode on CPU — slow, for parity debugging).

Policies are plain hashable scalars, so they travel safely through
``jax.jit`` static args and ``custom_vjp`` nondiff args.
"""
from __future__ import annotations

import functools
from typing import Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as kref
from . import topk_compress as tk

INTERPRET = True  # CPU container; flip to False on real TPU

Policy = Union[bool, str, None]

#: policy values accepted by ``resolve_policy``
POLICIES = (False, True, None, "off", "auto", "force")


def resolve_policy(policy: Policy) -> str:
    """Map a ``use_kernel`` policy to an execution mode: ``"global"``
    (legacy global top-k XLA), ``"xla"`` (fused blockwise XLA fallback),
    ``"interpret"`` (Pallas interpret mode), or ``"pallas"`` (compiled)."""
    if policy is None or policy is False or policy == "off":
        return "global"
    on_tpu = jax.default_backend() == "tpu"
    if policy is True or policy == "force":
        return "pallas" if on_tpu else "interpret"
    if policy == "auto":
        return "pallas" if on_tpu else "xla"
    raise ValueError(
        f"unknown kernel dispatch policy {policy!r}; expected one of "
        f"{POLICIES}")


def per_block_k(n: int, k: int, block: int = tk.DEFAULT_BLOCK) -> int:
    """Global k -> per-block k (ceil split over the tile grid)."""
    nb = -(-int(n) // block)
    return max(1, -(-int(k) // nb))


# ------------------------------------------------------------ dense masks --

@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def _blockwise_topk_mask(x, k_per_block, block, interpret):
    return tk.blockwise_topk_mask(x, k_per_block, block, interpret=interpret)


def blockwise_topk_mask(x: jax.Array, k_per_block: int,
                        block: int = tk.DEFAULT_BLOCK) -> jax.Array:
    return _blockwise_topk_mask(x, k_per_block, block, INTERPRET)


def topk_mask(x: jax.Array, k: int, block: int = tk.DEFAULT_BLOCK) -> jax.Array:
    """Global-k API -> per-block k (keeps ~k total, exact per block)."""
    n = int(np.prod(x.shape))
    return blockwise_topk_mask(x, per_block_k(n, k, block), block)


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def _ef_topk(x, residual, k_per_block, block, interpret):
    return tk.ef_topk(x, residual, k_per_block, block, interpret=interpret)


def ef_topk(x: jax.Array, residual: jax.Array, k_per_block: int,
            block: int = tk.DEFAULT_BLOCK):
    return _ef_topk(x, residual, k_per_block, block, INTERPRET)


# ------------------------------------------------- fused encode / decode --

@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def _encode_pallas(x, k_per_block, block, interpret):
    return tk.encode_topk(x, k_per_block, block, interpret=interpret)


@functools.partial(jax.jit, static_argnums=(2, 3))
def _decode_pallas(values, bitmap, shape, interpret):
    return tk.decode_topk(values, bitmap, shape, interpret=interpret)


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def _ef_encode_pallas(x, residual, k_per_block, block, interpret):
    return tk.ef_encode_topk(x, residual, k_per_block, block,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnums=(1, 2))
def xla_encode_topk(x: jax.Array, k_per_block: int,
                    block: int = tk.DEFAULT_BLOCK):
    """Fused blockwise encode under plain XLA — the CPU fallback of the
    ``"auto"`` policy (same selection semantics as the Pallas kernel)."""
    return kref.encode_topk_ref(x, k_per_block, block)


@functools.partial(jax.jit, static_argnums=(2,))
def xla_decode_topk(values: jax.Array, bitmap: jax.Array,
                    shape: Tuple[int, ...]):
    return kref.decode_topk_ref(values, bitmap, shape)


@functools.partial(jax.jit, static_argnums=(2, 3))
def xla_ef_encode_topk(x: jax.Array, residual: jax.Array, k_per_block: int,
                       block: int = tk.DEFAULT_BLOCK):
    return kref.ef_encode_topk_ref(x, residual, k_per_block, block)


def encode_topk(x: jax.Array, k_per_block: int,
                block: int = tk.DEFAULT_BLOCK, interpret=None):
    """Jitted fused wire encode (Pallas): (values, bitmap)."""
    return _encode_pallas(x, k_per_block, block,
                          INTERPRET if interpret is None else interpret)


def decode_topk(values: jax.Array, bitmap: jax.Array,
                shape: Tuple[int, ...], interpret=None):
    return _decode_pallas(values, bitmap, tuple(shape),
                          INTERPRET if interpret is None else interpret)


def ef_encode_topk(x: jax.Array, residual: jax.Array, k_per_block: int,
                   block: int = tk.DEFAULT_BLOCK, interpret=None):
    return _ef_encode_pallas(x, residual, k_per_block, block,
                             INTERPRET if interpret is None else interpret)


# ------------------------------------------------------- codec round trip --

def codec_topk_mask(x: jax.Array, k: int, mode: str,
                    block: int = tk.DEFAULT_BLOCK) -> jax.Array:
    """Wire-faithful sparsification: fused encode (threshold search + bitmap
    + packed-value compaction) then decode — the consumer sees exactly what
    the "mask" wire encoding carried.  ``mode`` is a resolved policy."""
    n = int(np.prod(x.shape))
    kpb = per_block_k(n, k, block)
    if mode == "xla":
        values, bitmap = xla_encode_topk(x, kpb, block)
        return xla_decode_topk(values, bitmap, x.shape)
    interpret = mode != "pallas"
    values, bitmap = encode_topk(x, kpb, block, interpret=interpret)
    return decode_topk(values, bitmap, x.shape, interpret=interpret)


def codec_ef_topk(x: jax.Array, residual: jax.Array, k: int, mode: str,
                  block: int = tk.DEFAULT_BLOCK
                  ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback codec round trip: (sent, new_residual), residual
    update fused into the encode kernel."""
    n = int(np.prod(x.shape))
    kpb = per_block_k(n, k, block)
    if mode == "xla":
        values, bitmap, newr = xla_ef_encode_topk(x, residual, kpb, block)
        return xla_decode_topk(values, bitmap, x.shape), newr
    interpret = mode != "pallas"
    values, bitmap, newr = ef_encode_topk(x, residual, kpb, block,
                                          interpret=interpret)
    return decode_topk(values, bitmap, x.shape, interpret=interpret), newr
