"""Jitted public wrappers over the Pallas Top-K kernels.

``topk_mask(x, k)`` matches :func:`repro.core.compression.topk_mask`'s
global-k signature by converting the global k into a per-block k (ceil
split).  Global and blockwise selections differ (documented: blockwise is
the standard approximation real compression kernels ship — it bounds the
worst-case block and parallelizes perfectly); convergence benchmarks compare
both (benchmarks/convergence.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import topk_compress as tk

INTERPRET = True  # CPU container; flip to False on real TPU


@functools.partial(jax.jit, static_argnums=(1, 2))
def blockwise_topk_mask(x: jax.Array, k_per_block: int,
                        block: int = tk.DEFAULT_BLOCK) -> jax.Array:
    return tk.blockwise_topk_mask(x, k_per_block, block, interpret=INTERPRET)


def topk_mask(x: jax.Array, k: int, block: int = tk.DEFAULT_BLOCK) -> jax.Array:
    """Global-k API -> per-block k (keeps ~k total, exact per block)."""
    n = int(np.prod(x.shape))
    nb = -(-n // block)
    k_per_block = max(1, -(-int(k) // nb))
    return blockwise_topk_mask(x, k_per_block, block)


@functools.partial(jax.jit, static_argnums=(2, 3))
def ef_topk(x: jax.Array, residual: jax.Array, k_per_block: int,
            block: int = tk.DEFAULT_BLOCK):
    return tk.ef_topk(x, residual, k_per_block, block, interpret=INTERPRET)
