"""Pure-jnp oracles for the Top-K compression kernels.

Selection semantics (shared by oracle and kernel, so comparisons are exact):
keep every element whose |value| is >= the k-th largest |value| in its block.
With ties at the threshold this keeps a *superset* of k elements — the same
superset in both implementations, because the kernel's binary search over
IEEE-754 bit patterns recovers exactly the k-th largest magnitude.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def topk_mask_ref(x: jax.Array, k: int) -> jax.Array:
    """Global Top-K by magnitude, dense output (threshold semantics)."""
    flat = x.reshape(-1)
    k = int(min(max(k, 1), flat.shape[0]))
    vals, _ = jax.lax.top_k(jnp.abs(flat).astype(jnp.float32), k)
    thr = vals[-1]
    keep = jnp.abs(flat).astype(jnp.float32) >= thr
    return jnp.where(keep, flat, 0).reshape(x.shape)


def _pad_to_blocks(flat: jax.Array, block: int) -> Tuple[jax.Array, int]:
    n = flat.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    return jnp.pad(flat, (0, pad)), nb


def blockwise_topk_mask_ref(x: jax.Array, k_per_block: int,
                            block: int = 4096) -> jax.Array:
    """Blockwise Top-K (what the TPU kernel computes): the flat tensor is
    split into ``block``-sized tiles, each keeping its own top k_per_block.
    Zero padding never wins selection (|0| below any positive threshold)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    padded, nb = _pad_to_blocks(flat, block)
    tiles = padded.reshape(nb, block)
    k = int(min(max(k_per_block, 1), block))
    mags = jnp.abs(tiles).astype(jnp.float32)
    vals, _ = jax.lax.top_k(mags, k)
    thr = vals[:, -1:]
    out = jnp.where(mags >= thr, tiles, 0)
    return out.reshape(-1)[:n].reshape(x.shape)


def ef_topk_ref(x: jax.Array, residual: jax.Array, k_per_block: int,
                block: int = 4096) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback variant: compress (x + residual), return
    (sent, new_residual)."""
    corrected = x + residual
    sent = blockwise_topk_mask_ref(corrected, k_per_block, block)
    return sent, corrected - sent


def count_kept(x: jax.Array) -> int:
    return int(jnp.sum(x != 0))
