"""Pure-jnp oracles for the Top-K compression kernels.

Selection semantics (shared by oracle and kernel, so comparisons are exact):
keep every element whose |value| is >= the k-th largest |value| in its block.
With ties at the threshold this keeps a *superset* of k elements — the same
superset in both implementations, because the kernel's binary search over
IEEE-754 bit patterns recovers exactly the k-th largest magnitude.

The *encode* oracles are different: a wire payload has fixed capacity, so
ties at the threshold are capped — among threshold-tied elements the first
``k - n_above`` in index order are kept, giving exactly ``min(k, block)``
slots per block.  The fused Pallas encode kernels implement the same rule,
so encode comparisons are also exact.

Wire format (the "mask" encoding priced by
:func:`repro.core.compression.wire_bytes`): per block of ``B`` elements
(``B`` a multiple of 32), a bitmap of ``B/32`` uint32 words (LSB-first
within each word) plus ``k`` packed values in index order.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def topk_mask_ref(x: jax.Array, k: int) -> jax.Array:
    """Global Top-K by magnitude, dense output (threshold semantics)."""
    flat = x.reshape(-1)
    k = int(min(max(k, 1), flat.shape[0]))
    vals, _ = jax.lax.top_k(jnp.abs(flat).astype(jnp.float32), k)
    thr = vals[-1]
    keep = jnp.abs(flat).astype(jnp.float32) >= thr
    return jnp.where(keep, flat, 0).reshape(x.shape)


def _pad_to_blocks(flat: jax.Array, block: int) -> Tuple[jax.Array, int]:
    n = flat.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    return jnp.pad(flat, (0, pad)), nb


def blockwise_topk_mask_ref(x: jax.Array, k_per_block: int,
                            block: int = 4096) -> jax.Array:
    """Blockwise Top-K (what the TPU kernel computes): the flat tensor is
    split into ``block``-sized tiles, each keeping its own top k_per_block.
    Zero padding never wins selection (|0| below any positive threshold)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    padded, nb = _pad_to_blocks(flat, block)
    tiles = padded.reshape(nb, block)
    k = int(min(max(k_per_block, 1), block))
    mags = jnp.abs(tiles).astype(jnp.float32)
    # barrier: XLA rewrites slice-of-top_k into a full per-row sort
    vals = jax.lax.optimization_barrier(jax.lax.top_k(mags, k)[0])
    thr = vals[:, -1:]
    out = jnp.where(mags >= thr, tiles, 0)
    return out.reshape(-1)[:n].reshape(x.shape)


def _force_rounding(x: jax.Array) -> jax.Array:
    """Pin storage-dtype rounding of a computed value (see the twin helper
    in :mod:`repro.kernels.topk_compress`): under jit, XLA on CPU can keep a
    bf16 sum in f32 on the path into the selection bitcast, diverging from
    the eagerly-rounded value."""
    if x.dtype == jnp.bfloat16:
        return jax.lax.reduce_precision(x, 8, 7)
    if x.dtype == jnp.float16:
        return jax.lax.reduce_precision(x, 5, 10)
    return x


def ef_topk_ref(x: jax.Array, residual: jax.Array, k_per_block: int,
                block: int = 4096) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback variant: compress (x + residual), return
    (sent, new_residual)."""
    corrected = _force_rounding(x + residual)
    sent = blockwise_topk_mask_ref(corrected, k_per_block, block)
    return sent, corrected - sent


def count_kept(x: jax.Array) -> int:
    return int(jnp.sum(x != 0))


# ---------------------------------------------------------------------------
# Fused wire-encode / decode oracles (tie-capped, fixed wire capacity)
# ---------------------------------------------------------------------------

def _mag_bits(tiles: jax.Array) -> jax.Array:
    """int32 bit patterns of |tiles| as float32 — order-isomorphic to the
    magnitude for non-negative floats (exactly what the kernel searches)."""
    return jax.lax.bitcast_convert_type(
        jnp.abs(tiles.astype(jnp.float32)), jnp.int32)


def _keep_capped(bits: jax.Array, k: int) -> jax.Array:
    """Boolean keep-mask with exactly min(k, B) kept per row: everything
    strictly above the k-th largest bit pattern, plus the first
    ``k - n_above`` threshold ties in index order.

    The threshold runs ``top_k`` on the *float* view of the bit patterns
    (order-isomorphic for the non-negative magnitudes ``_mag_bits``
    produces, so the selected element is identical): XLA:CPU's fast TopK
    custom call is float-only — an integer top_k falls back to a full
    sort, ~30x slower at bench shapes.  The ``optimization_barrier``
    stops XLA from rewriting slice-of-top_k back into that same sort."""
    mags = jax.lax.bitcast_convert_type(bits, jnp.float32)
    thr_m = jax.lax.optimization_barrier(jax.lax.top_k(mags, k)[0])[:, -1:]
    thr = jax.lax.bitcast_convert_type(thr_m, jnp.int32)
    above = bits > thr
    n_above = jnp.sum(above.astype(jnp.int32), axis=1, keepdims=True)
    tie = bits == thr
    tie_rank = jnp.cumsum(tie.astype(jnp.int32), axis=1)
    return above | (tie & (tie_rank <= (k - n_above)))


def pack_mask_ref(keep: jax.Array) -> jax.Array:
    """(nb, B) bool -> (nb, B//32) uint32 bitmap, LSB-first per word."""
    nb, B = keep.shape
    w = keep.reshape(nb, B // 32, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(w << shifts, axis=2, dtype=jnp.uint32)


def unpack_mask_ref(bitmap: jax.Array) -> jax.Array:
    """(nb, W) uint32 bitmap -> (nb, W*32) bool keep-mask."""
    nb, W = bitmap.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (bitmap[:, :, None] >> shifts) & jnp.uint32(1)
    return bits.astype(bool).reshape(nb, W * 32)


def encode_topk_ref(x: jax.Array, k_per_block: int,
                    block: int = 4096) -> Tuple[jax.Array, jax.Array]:
    """Fused wire encode: (values (nb, k) in index order, bitmap (nb, B/32)
    uint32).  Tie-capped — exactly k slots per block, the wire's capacity."""
    if block % 32:
        raise ValueError(f"block must be a multiple of 32, got {block}")
    flat = x.reshape(-1)
    padded, nb = _pad_to_blocks(flat, block)
    tiles = padded.reshape(nb, block)
    k = int(min(max(k_per_block, 1), block))
    # lax.top_k is index-stable on ties (lower index first), so its index
    # set IS the tie-capped keep set _keep_capped specifies — one fast-path
    # TopK call replaces the dense mask + cumsum + compaction pipeline
    # (tested equivalent against _keep_capped across dtypes/ties/zeros)
    mags = jnp.abs(tiles).astype(jnp.float32)
    idx = jnp.sort(jax.lax.top_k(mags, k)[1], axis=1)    # index order
    values = jnp.take_along_axis(tiles, idx, axis=1)
    word = (idx >> 5).astype(jnp.int32)
    bit = (idx & 31).astype(jnp.uint32)
    rows = jnp.broadcast_to(jnp.arange(nb, dtype=jnp.int32)[:, None],
                            idx.shape)
    bitmap = jnp.zeros((nb, block // 32), jnp.uint32).at[rows, word].add(
        jnp.uint32(1) << bit)
    return values, bitmap


def decode_topk_ref(values: jax.Array, bitmap: jax.Array,
                    shape: Tuple[int, ...]) -> jax.Array:
    """Inverse of :func:`encode_topk_ref`: dense tensor of ``shape``."""
    keep = unpack_mask_ref(bitmap)
    pos = jnp.cumsum(keep.astype(jnp.int32), axis=1) - 1
    idx = jnp.clip(pos, 0, values.shape[1] - 1)
    dense = jnp.where(keep, jnp.take_along_axis(values, idx, axis=1), 0)
    n = int(np.prod(shape))
    return dense.reshape(-1)[:n].reshape(shape)


def ef_encode_topk_ref(x: jax.Array, residual: jax.Array, k_per_block: int,
                       block: int = 4096
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused error-feedback wire encode: compress (x + residual), return
    (values, bitmap, new_residual) with new_residual = unsent corrected."""
    corrected = _force_rounding(x + residual)
    values, bitmap = encode_topk_ref(corrected, k_per_block, block)
    sent = decode_topk_ref(values, bitmap, corrected.shape)
    return values, bitmap, (corrected - sent).astype(x.dtype)
