"""Pallas TPU kernel: blockwise magnitude Top-K sparsification.

This is the TPU adaptation of FusionLLM §6's CUDA Top-K library ("faster
than PyTorch TopK").  A GPU kernel would partial-sort per thread block and
emit (values, indices); TPUs have no efficient scatter and the VPU hates
data-dependent permutation, so we rethink the algorithm (DESIGN.md §2):

* the tensor is tiled into VMEM blocks; each block selects its own top
  ``k`` — embarrassingly parallel over the grid, no cross-block traffic;
* the k-th largest magnitude is found *exactly* by a 31-step binary search
  over IEEE-754 bit patterns (for non-negative floats the int32 bit pattern
  is order-isomorphic to the value), every step being a dense
  compare+reduce — pure VPU work, no sort;
* the output stays **dense** (values below threshold zeroed).  The sparse
  wire encoding (mask bitmap + packed values) is a layout decision for the
  transport layer; on-chip we keep dense tiles so downstream matmuls feed
  the MXU directly.

``ef_topk`` fuses error-feedback (compress x+residual, emit new residual)
around the same threshold search — one extra VMEM-resident add/sub, no
extra HBM round-trip.

Kernels are validated in interpret mode against :mod:`repro.kernels.ref`
(exact equality — same selection set by construction).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096        # elements per grid step (fits VMEM many times
                            # over; multiple of 8*128 VPU tiles)
_SEARCH_BITS = 31           # full int32 positive range


def _kth_threshold_bits(mag_bits: jax.Array, k: jax.Array) -> jax.Array:
    """Largest t such that count(mag_bits >= t) >= k (t=0 if k >= n).

    mag_bits: int32 bit patterns of non-negative floats (monotone in value).
    31 fixed iterations of compare+reduce — branch-free, VPU-only.
    """
    lo = jnp.int32(0)
    hi = jnp.int32(0x7F800000)  # +inf bit pattern bounds every magnitude;
    # (also keeps hi - lo + 1 inside int32 — 2^31-1 would overflow)

    def body(_, carry):
        lo, hi = carry
        mid = lo + (hi - lo + 1) // 2
        cnt = jnp.sum((mag_bits >= mid).astype(jnp.int32))
        take = cnt >= k
        return (jnp.where(take, mid, lo), jnp.where(take, hi, mid - 1))

    lo, _ = jax.lax.fori_loop(0, _SEARCH_BITS, body, (lo, hi))
    return lo


def _topk_block_kernel(x_ref, o_ref, *, k: int):
    x = x_ref[...]
    mag = jnp.abs(x.astype(jnp.float32))
    bits = jax.lax.bitcast_convert_type(mag, jnp.int32)
    thr = _kth_threshold_bits(bits, jnp.int32(k))
    keep = bits >= thr
    o_ref[...] = jnp.where(keep, x, jnp.zeros_like(x))


def _ef_topk_block_kernel(x_ref, r_ref, sent_ref, newr_ref, *, k: int):
    corrected = x_ref[...] + r_ref[...]
    mag = jnp.abs(corrected.astype(jnp.float32))
    bits = jax.lax.bitcast_convert_type(mag, jnp.int32)
    thr = _kth_threshold_bits(bits, jnp.int32(k))
    keep = bits >= thr
    sent = jnp.where(keep, corrected, jnp.zeros_like(corrected))
    sent_ref[...] = sent
    newr_ref[...] = corrected - sent


def _grid_call(kernel, tiles: jax.Array, n_in: int, n_out: int, block: int,
               k: int, interpret: bool):
    nb = tiles.shape[0]
    shape = jax.ShapeDtypeStruct((nb, block), tiles.dtype)
    spec = pl.BlockSpec((1, block), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(kernel, k=k),
        grid=(nb,),
        in_specs=[spec] * n_in,
        out_specs=[spec] * n_out if n_out > 1 else spec,
        out_shape=[shape] * n_out if n_out > 1 else shape,
        interpret=interpret,
    )


def _prep(x: jax.Array, block: int) -> Tuple[jax.Array, int, Tuple[int, ...]]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    nb = -(-n // block)
    flat = jnp.pad(flat, (0, nb * block - n)).reshape(nb, block)
    return flat, n, x.shape


def blockwise_topk_mask(x: jax.Array, k_per_block: int,
                        block: int = DEFAULT_BLOCK,
                        interpret: bool = True) -> jax.Array:
    """Dense blockwise Top-K (Pallas).  interpret=True on CPU; on a real TPU
    pass interpret=False."""
    if x.dtype not in (jnp.float32, jnp.bfloat16, jnp.float16):
        raise TypeError(f"unsupported dtype {x.dtype}")
    k = int(min(max(k_per_block, 1), block))
    tiles, n, shape = _prep(x, block)
    out = _grid_call(_topk_block_kernel, tiles, 1, 1, block, k,
                     interpret)(tiles)
    return out.reshape(-1)[:n].reshape(shape)


def ef_topk(x: jax.Array, residual: jax.Array, k_per_block: int,
            block: int = DEFAULT_BLOCK,
            interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Fused error-feedback Top-K: (sent, new_residual)."""
    k = int(min(max(k_per_block, 1), block))
    tiles, n, shape = _prep(x, block)
    rtiles, _, _ = _prep(residual, block)
    fn = _grid_call(_ef_topk_block_kernel, tiles, 2, 2, block, k, interpret)
    sent, newr = fn(tiles, rtiles)
    return (sent.reshape(-1)[:n].reshape(shape),
            newr.reshape(-1)[:n].reshape(shape))
