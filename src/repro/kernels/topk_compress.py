"""Pallas TPU kernel: blockwise magnitude Top-K sparsification.

This is the TPU adaptation of FusionLLM §6's CUDA Top-K library ("faster
than PyTorch TopK").  A GPU kernel would partial-sort per thread block and
emit (values, indices); TPUs have no efficient scatter and the VPU hates
data-dependent permutation, so we rethink the algorithm (DESIGN.md §2):

* the tensor is tiled into VMEM blocks; each block selects its own top
  ``k`` — embarrassingly parallel over the grid, no cross-block traffic;
* the k-th largest magnitude is found *exactly* by a 31-step binary search
  over IEEE-754 bit patterns (for non-negative floats the int32 bit pattern
  is order-isomorphic to the value), every step being a dense
  compare+reduce — pure VPU work, no sort;
* the output stays **dense** (values below threshold zeroed).  The sparse
  wire encoding (mask bitmap + packed values) is a layout decision for the
  transport layer; on-chip we keep dense tiles so downstream matmuls feed
  the MXU directly.

``ef_topk`` fuses error-feedback (compress x+residual, emit new residual)
around the same threshold search — one extra VMEM-resident add/sub, no
extra HBM round-trip.

``encode_topk`` / ``ef_encode_topk`` / ``decode_topk`` are the fused *wire*
kernels: threshold search + mask-bitmap emission + packed-value compaction
in one pallas_call (the "mask" encoding `wire_bytes` prices).  Unlike the
dense kernels they are tie-capped — the wire has exactly k slots per block,
so among threshold ties the first ``k - n_above`` in index order win.  The
packed-value lane is padded to a multiple of 128 inside the kernel (TPU
lane width); wrappers slice it back to k.

Kernels are validated in interpret mode against :mod:`repro.kernels.ref`
(exact equality — same selection set by construction).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096        # elements per grid step (fits VMEM many times
                            # over; multiple of 8*128 VPU tiles)
_SEARCH_BITS = 31           # full int32 positive range
_LANE = 128                 # TPU lane width: packed-value capacity rounding


def _kth_threshold_bits(mag_bits: jax.Array, k: jax.Array) -> jax.Array:
    """Largest t such that count(mag_bits >= t) >= k (t=0 if k >= n).

    mag_bits: int32 bit patterns of non-negative floats (monotone in value).
    31 fixed iterations of compare+reduce — branch-free, VPU-only.
    """
    lo = jnp.int32(0)
    hi = jnp.int32(0x7F800000)  # +inf bit pattern bounds every magnitude;
    # (also keeps hi - lo + 1 inside int32 — 2^31-1 would overflow)

    def body(_, carry):
        lo, hi = carry
        mid = lo + (hi - lo + 1) // 2
        cnt = jnp.sum((mag_bits >= mid).astype(jnp.int32))
        take = cnt >= k
        return (jnp.where(take, mid, lo), jnp.where(take, hi, mid - 1))

    lo, _ = jax.lax.fori_loop(0, _SEARCH_BITS, body, (lo, hi))
    return lo


def _force_rounding(x: jax.Array) -> jax.Array:
    """Pin storage-dtype rounding of a computed value.  XLA on CPU computes
    bf16 arithmetic in f32 and may fuse away the round-trip on the path into
    the bitcast, so (x + r) inside a kernel can carry more precision than the
    eagerly-materialized oracle value — this makes selection bit-exact."""
    if x.dtype == jnp.bfloat16:
        return jax.lax.reduce_precision(x, 8, 7)
    if x.dtype == jnp.float16:
        return jax.lax.reduce_precision(x, 5, 10)
    return x


def _topk_block_kernel(x_ref, o_ref, *, k: int):
    x = x_ref[...]
    mag = jnp.abs(x.astype(jnp.float32))
    bits = jax.lax.bitcast_convert_type(mag, jnp.int32)
    thr = _kth_threshold_bits(bits, jnp.int32(k))
    keep = bits >= thr
    o_ref[...] = jnp.where(keep, x, jnp.zeros_like(x))


def _ef_topk_block_kernel(x_ref, r_ref, sent_ref, newr_ref, *, k: int):
    corrected = _force_rounding(x_ref[...] + r_ref[...])
    mag = jnp.abs(corrected.astype(jnp.float32))
    bits = jax.lax.bitcast_convert_type(mag, jnp.int32)
    thr = _kth_threshold_bits(bits, jnp.int32(k))
    keep = bits >= thr
    sent = jnp.where(keep, corrected, jnp.zeros_like(corrected))
    sent_ref[...] = sent
    newr_ref[...] = corrected - sent


def _grid_call(kernel, tiles: jax.Array, n_in: int, n_out: int, block: int,
               k: int, interpret: bool):
    nb = tiles.shape[0]
    shape = jax.ShapeDtypeStruct((nb, block), tiles.dtype)
    spec = pl.BlockSpec((1, block), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(kernel, k=k),
        grid=(nb,),
        in_specs=[spec] * n_in,
        out_specs=[spec] * n_out if n_out > 1 else spec,
        out_shape=[shape] * n_out if n_out > 1 else shape,
        interpret=interpret,
    )


def _prep(x: jax.Array, block: int) -> Tuple[jax.Array, int, Tuple[int, ...]]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    nb = -(-n // block)
    flat = jnp.pad(flat, (0, nb * block - n)).reshape(nb, block)
    return flat, n, x.shape


def blockwise_topk_mask(x: jax.Array, k_per_block: int,
                        block: int = DEFAULT_BLOCK,
                        interpret: bool = True) -> jax.Array:
    """Dense blockwise Top-K (Pallas).  interpret=True on CPU; on a real TPU
    pass interpret=False."""
    if x.dtype not in (jnp.float32, jnp.bfloat16, jnp.float16):
        raise TypeError(f"unsupported dtype {x.dtype}")
    k = int(min(max(k_per_block, 1), block))
    tiles, n, shape = _prep(x, block)
    out = _grid_call(_topk_block_kernel, tiles, 1, 1, block, k,
                     interpret)(tiles)
    return out.reshape(-1)[:n].reshape(shape)


def ef_topk(x: jax.Array, residual: jax.Array, k_per_block: int,
            block: int = DEFAULT_BLOCK,
            interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Fused error-feedback Top-K: (sent, new_residual)."""
    k = int(min(max(k_per_block, 1), block))
    tiles, n, shape = _prep(x, block)
    rtiles, _, _ = _prep(residual, block)
    fn = _grid_call(_ef_topk_block_kernel, tiles, 2, 2, block, k, interpret)
    sent, newr = fn(tiles, rtiles)
    return (sent.reshape(-1)[:n].reshape(shape),
            newr.reshape(-1)[:n].reshape(shape))


# ---------------------------------------------------------------------------
# Fused wire-encode / decode kernels
# ---------------------------------------------------------------------------

def _keep_capped_block(x: jax.Array, k: int):
    """Tie-capped keep-mask for one (1, B) tile: exactly k kept.  Everything
    strictly above the k-th largest bit pattern, plus the first
    ``k - n_above`` threshold ties in index order."""
    bits = jax.lax.bitcast_convert_type(
        jnp.abs(x.astype(jnp.float32)), jnp.int32)
    thr = _kth_threshold_bits(bits, jnp.int32(k))
    above = bits > thr
    n_above = jnp.sum(above.astype(jnp.int32))
    tie = bits == thr
    tie_rank = jnp.cumsum(tie.astype(jnp.int32), axis=1)
    return above | (tie & (tie_rank <= (k - n_above)))


def _emit_encoded(x: jax.Array, keep: jax.Array, v_ref, m_ref, *, kp: int):
    """Write bitmap words (LSB-first) and index-order packed values."""
    B = x.shape[1]
    w = keep.reshape(B // 32, 32).astype(jnp.uint32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (B // 32, 32), 1)
    m_ref[...] = jnp.sum(w << shifts, axis=1,
                         dtype=jnp.uint32).reshape(1, B // 32)
    pos = jnp.cumsum(keep.astype(jnp.int32), axis=1) - 1
    safe = jnp.where(keep, pos, kp).reshape(-1)  # kp is out of range: dropped
    packed = jnp.zeros((kp,), x.dtype).at[safe].set(x.reshape(-1),
                                                    mode="drop")
    v_ref[...] = packed.reshape(1, kp)


def _encode_block_kernel(x_ref, v_ref, m_ref, *, k: int, kp: int):
    x = x_ref[...]
    _emit_encoded(x, _keep_capped_block(x, k), v_ref, m_ref, kp=kp)


def _ef_encode_block_kernel(x_ref, r_ref, v_ref, m_ref, newr_ref, *,
                            k: int, kp: int):
    corrected = _force_rounding(x_ref[...] + r_ref[...])
    keep = _keep_capped_block(corrected, k)
    _emit_encoded(corrected, keep, v_ref, m_ref, kp=kp)
    newr_ref[...] = jnp.where(keep, jnp.zeros_like(corrected), corrected)


def _decode_block_kernel(v_ref, m_ref, o_ref, *, kp: int):
    words = m_ref[...].reshape(-1)
    W = words.shape[0]
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (W, 32), 1)
    keep = ((words[:, None] >> shifts) & jnp.uint32(1)
            ).astype(bool).reshape(1, W * 32)
    pos = jnp.cumsum(keep.astype(jnp.int32), axis=1) - 1
    idx = jnp.clip(pos, 0, kp - 1).reshape(-1)
    vals = v_ref[...].reshape(-1)
    dense = jnp.where(keep, vals[idx].reshape(1, W * 32), 0)
    o_ref[...] = dense.astype(o_ref.dtype)


def _lane_pad(k: int) -> int:
    return -(-k // _LANE) * _LANE


def encode_topk(x: jax.Array, k_per_block: int, block: int = DEFAULT_BLOCK,
                interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Fused wire encode: (values (nb, k) in index order, bitmap (nb, B/32)
    uint32) in one pallas_call per tile.  Exactly k slots per block."""
    if x.dtype not in (jnp.float32, jnp.bfloat16, jnp.float16):
        raise TypeError(f"unsupported dtype {x.dtype}")
    if block % 32:
        raise ValueError(f"block must be a multiple of 32, got {block}")
    k = int(min(max(k_per_block, 1), block))
    kp = _lane_pad(k)
    tiles, _, _ = _prep(x, block)
    nb = tiles.shape[0]
    W = block // 32
    values, bitmap = pl.pallas_call(
        functools.partial(_encode_block_kernel, k=k, kp=kp),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, kp), lambda i: (i, 0)),
                   pl.BlockSpec((1, W), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, kp), tiles.dtype),
                   jax.ShapeDtypeStruct((nb, W), jnp.uint32)],
        interpret=interpret,
    )(tiles)
    return values[:, :k], bitmap


def ef_encode_topk(x: jax.Array, residual: jax.Array, k_per_block: int,
                   block: int = DEFAULT_BLOCK, interpret: bool = True
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused error-feedback wire encode: compress (x + residual) and emit
    (values, bitmap, new_residual) — residual update in the same kernel."""
    if block % 32:
        raise ValueError(f"block must be a multiple of 32, got {block}")
    k = int(min(max(k_per_block, 1), block))
    kp = _lane_pad(k)
    tiles, n, shape = _prep(x, block)
    rtiles, _, _ = _prep(residual, block)
    nb = tiles.shape[0]
    W = block // 32
    in_spec = pl.BlockSpec((1, block), lambda i: (i, 0))
    values, bitmap, newr = pl.pallas_call(
        functools.partial(_ef_encode_block_kernel, k=k, kp=kp),
        grid=(nb,),
        in_specs=[in_spec, in_spec],
        out_specs=[pl.BlockSpec((1, kp), lambda i: (i, 0)),
                   pl.BlockSpec((1, W), lambda i: (i, 0)),
                   in_spec],
        out_shape=[jax.ShapeDtypeStruct((nb, kp), tiles.dtype),
                   jax.ShapeDtypeStruct((nb, W), jnp.uint32),
                   jax.ShapeDtypeStruct((nb, block), tiles.dtype)],
        interpret=interpret,
    )(tiles, rtiles)
    return values[:, :k], bitmap, newr.reshape(-1)[:n].reshape(shape)


def decode_topk(values: jax.Array, bitmap: jax.Array,
                shape: Tuple[int, ...], interpret: bool = True) -> jax.Array:
    """Inverse of :func:`encode_topk`: dense tensor of ``shape``."""
    nb, k = values.shape
    W = bitmap.shape[1]
    block = W * 32
    kp = _lane_pad(k)
    if kp != k:
        values = jnp.pad(values, ((0, 0), (0, kp - k)))
    dense = pl.pallas_call(
        functools.partial(_decode_block_kernel, kp=kp),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, kp), lambda i: (i, 0)),
                  pl.BlockSpec((1, W), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), values.dtype),
        interpret=interpret,
    )(values, bitmap)
    n = int(np.prod(shape))
    return dense.reshape(-1)[:n].reshape(shape)
