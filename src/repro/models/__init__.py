"""Model zoo: unified causal LM (dense/MoE/hybrid/xLSTM/VLM) + enc-dec."""
from . import attention, causal_lm, encdec, layers, moe, ssm, xlstm
