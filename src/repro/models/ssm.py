"""Mamba2 (SSD) blocks — chunked matmul formulation, TPU-native.

The GPU reference implements SSD with a fused Triton kernel; the TPU
adaptation here computes the same recurrence

    h_t = a_t · h_{t-1} + Δt_t · B_t ⊗ x_t          a_t = exp(A·Δt_t)
    y_t = C_t · h_t + D · x_t

in *chunked* form: the sequence splits into chunks of length Lc; intra-chunk
terms are dense matmuls (MXU-friendly, the whole point of SSD), inter-chunk
terms are a short ``lax.scan`` over per-chunk states (S/Lc steps).  ngroups=1
(B/C shared across heads), scalar A per head — the Mamba2 defaults.

Decode is the O(1)-state recurrence (``mamba_decode_step``), which is why
SSM/hybrid architectures run the 500k-token decode shape (DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from .layers import causal_conv1d, causal_conv1d_init, causal_conv1d_step, \
    dense_init, rmsnorm, rmsnorm_init


class MambaCfg(NamedTuple):
    d_model: int
    d_inner: int          # expand * d_model
    n_heads: int          # d_inner // head_dim
    head_dim: int
    d_state: int          # ssm_state (assigned: 64)
    conv_width: int = 4
    chunk: int = 128


class MambaState(NamedTuple):
    """Decode cache for one layer."""
    h: jax.Array          # (B, nh, d_state, head_dim)
    conv: jax.Array       # (B, conv_width-1, d_inner + 2*d_state)


def mamba_init(rng: jax.Array, cfg: MambaCfg, dtype=jnp.float32) -> Dict[str, Any]:
    d, di, nh, ds = cfg.d_model, cfg.d_inner, cfg.n_heads, cfg.d_state
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    d_in_proj = 2 * di + 2 * ds + nh          # z, x, B, C, dt
    conv_ch = di + 2 * ds
    # dt bias: softplus^-1 of dt in [1e-3, 1e-1] (mamba default init)
    u = jax.random.uniform(k3, (nh,), minval=math.log(1e-3), maxval=math.log(1e-1))
    dt0 = jnp.exp(u)
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    return {
        "in_proj": dense_init(k1, d, d_in_proj, dtype),
        "conv": causal_conv1d_init(k2, conv_ch, cfg.conv_width, dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": rmsnorm_init(di, dtype),
        "out_proj": dense_init(k4, di, d, dtype, scale=1.0 / math.sqrt(di)),
    }


def _split_proj(cfg: MambaCfg, zxbcdt: jax.Array):
    di, ds, nh = cfg.d_inner, cfg.d_state, cfg.n_heads
    z, x, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + ds, 2 * di + 2 * ds], axis=-1)
    return z, x, Bc, Cc, dt


def _segsum_chunk(log_a: jax.Array) -> jax.Array:
    """log_a: (..., Lc).  Returns (..., Lc, Lc) with [l, m] = Σ_{j=m+1..l},
    -inf above the diagonal (strictly causal cumulative decay)."""
    L = log_a.shape[-1]
    s = jnp.cumsum(log_a, axis=-1)
    diff = s[..., :, None] - s[..., None, :]      # s_l - s_m
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                Bm: jax.Array, Cm: jax.Array, D: jax.Array,
                chunk: int, h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Structured state-space duality, chunked.

    x: (B,S,nh,hd); dt: (B,S,nh) (post-softplus); A: (nh,) negative;
    Bm, Cm: (B,S,ds); D: (nh,).  Returns (y (B,S,nh,hd), h_final
    (B,nh,ds,hd))."""
    Bsz, S, nh, hd = x.shape
    ds = Bm.shape[-1]
    if S % chunk != 0:
        raise ValueError(f"seq {S} not divisible by chunk {chunk}")
    nc = S // chunk
    xc = x.reshape(Bsz, nc, chunk, nh, hd)
    dtc = dt.reshape(Bsz, nc, chunk, nh)
    Bc = Bm.reshape(Bsz, nc, chunk, ds)
    Cc = Cm.reshape(Bsz, nc, chunk, ds)

    # SSM heads ride the model axis: the (B,nc,nh,Lc,Lc) decay tensor is the
    # memory hot-spot of chunked SSD — head-sharding it divides the footprint
    # by the TP degree (nh=112 is divisible by 16 for zamba2-7b).
    xc = constrain(xc, ("batch", None, None, "model", None))
    dtc = constrain(dtc, ("batch", None, None, "model"))
    log_a = (A[None, None, None, :] * dtc)             # (B,nc,Lc,nh) ≤ 0
    log_a = constrain(log_a, ("batch", None, None, "model"))
    seg = _segsum_chunk(jnp.moveaxis(log_a, -1, -2))   # (B,nc,nh,Lc,Lc)
    seg = constrain(seg, ("batch", None, "model", None, None))
    decay = jnp.exp(seg)
    decay = constrain(decay, ("batch", None, "model", None, None))

    # intra-chunk: y[l] += Σ_{m≤l} (C_l·B_m) exp(s_l-s_m) dt_m x_m
    # Multi-operand einsums are decomposed MANUALLY: letting XLA pick the
    # contraction order materialized a rank-7 (B,nc,Lc,nh,ds,hd) outer
    # product as a scan residual — 14 GiB/layer at zamba2-7b scale
    # (measured; see EXPERIMENTS.md §Perf).  The orders below keep every
    # intermediate ≤ rank 6 with the head axis sharded.
    CB = jnp.einsum("bcls,bcms->bclm", Cc, Bc)          # (B,nc,Lc,Lc)
    W = CB[:, :, None] * decay                          # (B,nc,nh,Lc,Lc)
    Wdt = W * jnp.moveaxis(dtc, -1, -2)[:, :, :, None, :].astype(W.dtype)
    Y_intra = jnp.einsum("bchlm,bcmhp->bclhp", Wdt, xc.astype(W.dtype))

    # per-chunk outgoing state: H_c = Σ_m exp(s_last-s_m) dt_m B_m ⊗ x_m
    s_cum = jnp.cumsum(log_a, axis=2)                   # (B,nc,Lc,nh)
    w_out = jnp.exp(s_cum[:, :, -1:, :] - s_cum) * dtc  # (B,nc,Lc,nh)
    wx = w_out[..., None] * xc.astype(w_out.dtype)      # (B,nc,Lc,nh,hd)
    H = jnp.einsum("bclhp,bcls->bchsp", wx,
                   Bc.astype(wx.dtype))                 # (B,nc,nh,ds,hd)
    chunk_decay = jnp.exp(s_cum[:, :, -1, :])           # (B,nc,nh)

    # inter-chunk recurrence: h_{c} = decay_c · h_{c-1} + H_c  (scan over nc)
    # State runs in f32 regardless of activation dtype — the recurrence
    # accumulates products of decays and bf16 carries both lose precision
    # and break scan carry-type invariance (dt/decay are f32).
    def step(h, inp):
        dec, Hc = inp
        h_new = dec[:, :, None, None] * h + Hc.astype(jnp.float32)
        return h_new, h
    h_init = (h0.astype(jnp.float32) if h0 is not None
              else jnp.zeros((Bsz, nh, ds, hd), jnp.float32))
    h_last, h_starts = jax.lax.scan(
        step, h_init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(H, 1, 0)))
    h_starts = jnp.moveaxis(h_starts, 0, 1)             # (B,nc,nh,ds,hd) state at chunk start

    # inter-chunk contribution: y[l] += exp(s_l) C_l · h_start
    Ch = jnp.einsum("bcls,bchsp->bclhp", Cc.astype(jnp.float32), h_starts)
    Y_inter = Ch * jnp.exp(s_cum)[..., None]
    y = (Y_intra.astype(jnp.float32) + Y_inter).reshape(Bsz, S, nh, hd)
    y = y.astype(x.dtype) + x * D[None, None, :, None].astype(x.dtype)
    return y, h_last


def mamba_train(p, x: jax.Array, cfg: MambaCfg) -> jax.Array:
    """Full-sequence Mamba2 block body (no residual/out-norm — the caller
    owns the residual stream)."""
    B, S, _ = x.shape
    zxbcdt = x @ p["in_proj"]["w"].astype(x.dtype)
    z, xi, Bc, Cc, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xi, Bc, Cc], axis=-1)
    conv_out = jax.nn.silu(causal_conv1d(p["conv"], conv_in))
    xi, Bc, Cc = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + cfg.d_state],
                           axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(B, S, cfg.n_heads, cfg.head_dim)
    y, _ = ssd_chunked(xh, dt, A, Bc, Cc, p["D"], cfg.chunk)
    y = y.reshape(B, S, cfg.d_inner)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z)
    return y @ p["out_proj"]["w"].astype(x.dtype)


def mamba_prefill(p, x: jax.Array, cfg: MambaCfg
                  ) -> Tuple[jax.Array, MambaState]:
    """Full-sequence forward that also emits the decode state (final SSM
    state + conv tail), so decoding can continue after the prompt."""
    B, S, _ = x.shape
    zxbcdt = x @ p["in_proj"]["w"].astype(x.dtype)
    z, xi, Bc, Cc, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xi, Bc, Cc], axis=-1)
    conv_tail = conv_in[:, S - (cfg.conv_width - 1):, :]
    conv_out = jax.nn.silu(causal_conv1d(p["conv"], conv_in))
    xi, Bc, Cc = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + cfg.d_state],
                           axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(B, S, cfg.n_heads, cfg.head_dim)
    y, h_last = ssd_chunked(xh, dt, A, Bc, Cc, p["D"], cfg.chunk)
    y = y.reshape(B, S, cfg.d_inner)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z)
    return (y @ p["out_proj"]["w"].astype(x.dtype),
            MambaState(h=h_last.astype(x.dtype), conv=conv_tail))


def mamba_state_init(cfg: MambaCfg, batch: int, dtype=jnp.float32) -> MambaState:
    return MambaState(
        h=jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.head_dim), dtype),
        conv=jnp.zeros((batch, cfg.conv_width - 1,
                        cfg.d_inner + 2 * cfg.d_state), dtype))


def mamba_decode_step(p, x_t: jax.Array, state: MambaState, cfg: MambaCfg
                      ) -> Tuple[jax.Array, MambaState]:
    """One token. x_t: (B, d_model) -> (y_t (B, d_model), new state)."""
    zxbcdt = x_t @ p["in_proj"]["w"].astype(x_t.dtype)
    z, xi, Bc, Cc, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xi, Bc, Cc], axis=-1)
    conv_out, new_window = causal_conv1d_step(p["conv"], conv_in, state.conv)
    conv_out = jax.nn.silu(conv_out)
    xi, Bc, Cc = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + cfg.d_state],
                           axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])  # (B,nh)
    a = jnp.exp(-jnp.exp(p["A_log"])[None, :] * dt)                       # (B,nh)
    xh = xi.reshape(x_t.shape[0], cfg.n_heads, cfg.head_dim)
    # h = a·h + dt · B ⊗ x
    upd = jnp.einsum("bh,bs,bhp->bhsp", dt.astype(x_t.dtype), Bc, xh)
    h = a[:, :, None, None].astype(x_t.dtype) * state.h + upd
    y = jnp.einsum("bs,bhsp->bhp", Cc, h) + xh * p["D"][None, :, None].astype(x_t.dtype)
    y = y.reshape(x_t.shape[0], cfg.d_inner)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z)
    return y @ p["out_proj"]["w"].astype(x_t.dtype), MambaState(h=h, conv=new_window)


def ssd_reference(x, dt, A, Bm, Cm, D):
    """O(S) sequential oracle for tests: literal recurrence."""
    Bsz, S, nh, hd = x.shape
    ds = Bm.shape[-1]
    h = jnp.zeros((Bsz, nh, ds, hd), jnp.float32)
    ys = []
    for t in range(S):
        a = jnp.exp(A[None, :] * dt[:, t]).astype(jnp.float32)  # (B,nh)
        upd = jnp.einsum("bh,bs,bhp->bhsp", dt[:, t], Bm[:, t], x[:, t])
        h = a[:, :, None, None] * h + upd
        y = jnp.einsum("bs,bhsp->bhp", Cm[:, t], h) + x[:, t] * D[None, :, None]
        ys.append(y)
    return jnp.stack(ys, axis=1)


def mamba_flops(tokens: int, cfg: MambaCfg) -> float:
    """Forward FLOPs: projections + conv + SSD (intra-chunk matmul terms)."""
    d, di, ds, nh = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    proj = 2.0 * tokens * d * (2 * di + 2 * ds + nh) + 2.0 * tokens * di * d
    conv = 2.0 * tokens * (di + 2 * ds) * cfg.conv_width
    Lc = cfg.chunk
    ssd = (2.0 * tokens * Lc * ds                 # CB^T
           + 2.0 * tokens * Lc * nh * cfg.head_dim   # (CB·decay·dt) @ x
           + 4.0 * tokens * ds * nh * cfg.head_dim)  # state in/out
    return proj + conv + ssd
