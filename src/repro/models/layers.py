"""Shared neural-net layers (pure JAX, framework-free).

Every layer is an (init, apply) pair of pure functions; params are plain
dicts of jnp arrays so they stack cleanly for ``lax.scan`` over layers and
shard cleanly under GSPMD.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------------- norms --
def rmsnorm_init(d: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p: Dict[str, jax.Array], x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {"scale": jnp.ones((d,), dtype=dtype),
            "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(p: Dict[str, jax.Array], x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


def norm_init(kind: str, d: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
    return layernorm_init(d, dtype) if kind == "layernorm" else rmsnorm_init(d, dtype)


def norm_apply(kind: str, p, x: jax.Array) -> jax.Array:
    return layernorm(p, x) if kind == "layernorm" else rmsnorm(p, x)


# ----------------------------------------------------------------- linears --
def dense_init(rng: jax.Array, d_in: int, d_out: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> Dict[str, jax.Array]:
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return {"w": (jax.random.normal(rng, (d_in, d_out)) * s).astype(dtype)}


def dense(p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    return x @ p["w"].astype(x.dtype)


def embed_init(rng: jax.Array, vocab: int, d: int, dtype=jnp.float32
               ) -> Dict[str, jax.Array]:
    return {"table": (jax.random.normal(rng, (vocab, d)) * 0.02).astype(dtype)}


def embed(p: Dict[str, jax.Array], tokens: jax.Array, dtype=None) -> jax.Array:
    t = p["table"]
    if dtype is not None:
        t = t.astype(dtype)
    return jnp.take(t, tokens, axis=0)


# -------------------------------------------------------------------- RoPE --
def rope_freqs(head_dim: int, rope_fraction: float = 1.0,
               theta: float = 10_000.0) -> np.ndarray:
    """Inverse frequencies for the rotated slice of the head dim."""
    rot = int(head_dim * rope_fraction) // 2 * 2
    return 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float64) / rot))


def apply_rope(x: jax.Array, positions: jax.Array, rope_fraction: float = 1.0,
               theta: float = 10_000.0) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    rot = int(hd * rope_fraction) // 2 * 2
    inv = jnp.asarray(rope_freqs(hd, rope_fraction, theta), dtype=jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., S, rot/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr, xp], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------- FFN --
def mlp_init(rng: jax.Array, d: int, d_ff: int, act: str = "silu",
             dtype=jnp.float32) -> Dict[str, jax.Array]:
    k1, k2, k3 = jax.random.split(rng, 3)
    p = {"up": dense_init(k2, d, d_ff, dtype),
         "down": dense_init(k3, d_ff, d, dtype, scale=1.0 / math.sqrt(d_ff))}
    if act in ("silu", "swiglu"):
        p["gate"] = dense_init(k1, d, d_ff, dtype)
    return p


def mlp(p: Dict[str, jax.Array], x: jax.Array, act: str = "silu") -> jax.Array:
    if act in ("silu", "swiglu"):
        h = jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x)
    elif act == "gelu":
        h = jax.nn.gelu(dense(p["up"], x))
    else:
        raise ValueError(f"unknown act {act!r}")
    return dense(p["down"], h)


def mlp_flops(tokens: int, d: int, d_ff: int, act: str = "silu") -> float:
    mults = 3 if act in ("silu", "swiglu") else 2
    return 2.0 * tokens * d * d_ff * mults


# -------------------------------------------------------------------- loss --
def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_id: int = -1, z_loss: float = 0.0) -> jax.Array:
    """Mean token cross-entropy; labels == ignore_id are masked out."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss > 0.0:
        nll = nll + z_loss * jnp.square(lse)
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# -------------------------------------------------------------- conv (SSM) --
def causal_conv1d_init(rng: jax.Array, channels: int, width: int,
                       dtype=jnp.float32) -> Dict[str, jax.Array]:
    s = 1.0 / math.sqrt(width)
    return {"w": (jax.random.uniform(rng, (width, channels), minval=-s, maxval=s)
                  ).astype(dtype),
            "b": jnp.zeros((channels,), dtype=dtype)}


def causal_conv1d(p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. x: (B, S, C)."""
    w = p["w"].astype(x.dtype)           # (W, C)
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):               # width is tiny (4); unrolled taps
        out = out + pad[:, i:i + x.shape[1], :] * w[i]
    return out + p["b"].astype(x.dtype)


def causal_conv1d_step(p: Dict[str, jax.Array], x_t: jax.Array,
                       window: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. x_t: (B, C); window: (B, W-1, C) past inputs.
    Returns (y_t, new_window)."""
    w = p["w"].astype(x_t.dtype)         # (W, C)
    width = w.shape[0]
    full = jnp.concatenate([window, x_t[:, None, :]], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", full, w) + p["b"].astype(x_t.dtype)
    return y, full[:, 1:, :]
