"""Unified causal LM covering every decoder-only family in the assigned pool:

* ``dense``  — (GQA + SwiGLU/GELU) × L                 (llama3, mistral-nemo,
               stablelm, granite, gpt2)
* ``moe``    — (GQA + MoE) × L                         (mixtral, deepseek-moe)
* ``hybrid`` — Mamba2 × L with shared attention blocks (zamba2)
* ``xlstm``  — mLSTM/sLSTM pattern                     (xlstm-1.3b)
* ``vlm``    — dense/hybrid LM consuming stub patch embeddings (internvl2)

Layers are *scanned* over stacked parameters (compile time O(1) in depth);
the scan structure is exported via :func:`segments` so the roofline harness
can multiply per-body costs by trip counts (XLA cost_analysis counts a while
body once — measured, see EXPERIMENTS.md §Roofline methodology).

Three entry points used by the launcher / dry-run:
  ``init``          params
  ``train_loss``    full-sequence teacher-forced loss (train_4k)
  ``prefill``       full-sequence forward + cache      (prefill_32k)
  ``decode_step``   one token against a cache          (decode_32k, long_500k)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelCfg
from repro.distributed.sharding import constrain
from . import attention as attn
from . import moe as moe_mod
from . import ssm
from . import xlstm as xl
from .scan_config import scan as _scan
from .layers import (cross_entropy, dense, dense_init, embed, embed_init,
                     mlp, mlp_init, norm_apply, norm_init)


class Segment(NamedTuple):
    name: str       # params/cache key
    kind: str       # dense | moe | mamba | zamba_group | xlstm_group
    count: int      # scan trip count
    inner: int = 0  # inner layers per trip (grouped kinds)


def segments(cfg: ModelCfg) -> List[Segment]:
    if cfg.family in ("dense", "vlm"):
        return [Segment("blocks", "dense", cfg.n_layers)]
    if cfg.family == "moe":
        return [Segment("blocks", "moe", cfg.n_layers)]
    if cfg.family == "hybrid":
        g, rem = divmod(cfg.n_layers, cfg.attn_every)
        segs = [Segment("groups", "zamba_group", g, cfg.attn_every)]
        if rem:
            segs.append(Segment("tail", "mamba", rem))
        return segs
    if cfg.family == "xlstm":
        g, rem = divmod(cfg.n_layers, cfg.slstm_every)
        segs = [Segment("groups", "xlstm_group", g, cfg.slstm_every)]
        if rem:
            segs.append(Segment("tail", "mlstm", rem))
        return segs
    raise ValueError(f"unknown family {cfg.family!r}")


def _mamba_cfg(cfg: ModelCfg) -> ssm.MambaCfg:
    d_inner = cfg.ssm_expand * cfg.d_model
    return ssm.MambaCfg(d_model=cfg.d_model, d_inner=d_inner,
                        n_heads=d_inner // cfg.ssm_head_dim,
                        head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state,
                        chunk=cfg.ssm_chunk)


def _xlstm_cfg(cfg: ModelCfg) -> xl.XLSTMCfg:
    return xl.XLSTMCfg(d_model=cfg.d_model, n_heads=cfg.n_heads)


# ==================================================================== init ==
def _dense_block_init(rng, cfg: ModelCfg):
    k1, k2 = jax.random.split(rng)
    return {"ln1": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
            "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim,
                                   cfg.param_dtype, cfg.qkv_bias),
            "ln2": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act,
                            cfg.param_dtype)}


def _moe_block_init(rng, cfg: ModelCfg):
    k1, k2 = jax.random.split(rng)
    return {"ln1": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
            "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim,
                                   cfg.param_dtype, cfg.qkv_bias),
            "ln2": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
            "moe": moe_mod.moe_init(k2, cfg.d_model, cfg.d_ff, cfg.n_experts,
                                    cfg.n_shared_experts,
                                    dtype=cfg.param_dtype)}


def _mamba_block_init(rng, cfg: ModelCfg):
    return {"ln": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
            "mamba": ssm.mamba_init(rng, _mamba_cfg(cfg), cfg.param_dtype)}


def _shared_attn_init(rng, cfg: ModelCfg):
    """Zamba2 shared transformer block: attention + MLP (the assigned
    d_ff=14336 lives here), weights reused across invocations."""
    k1, k2 = jax.random.split(rng)
    p = {"ln": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
         "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads,
                                cfg.n_kv_heads, cfg.head_dim,
                                cfg.param_dtype)}
    if cfg.d_ff > 0:
        p["ln2"] = norm_init(cfg.norm, cfg.d_model, cfg.param_dtype)
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act,
                            cfg.param_dtype)
    return p


def _mlstm_block_init(rng, cfg: ModelCfg):
    return {"ln": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
            "mlstm": xl.mlstm_init(rng, _xlstm_cfg(cfg), cfg.param_dtype)}


def _slstm_block_init(rng, cfg: ModelCfg):
    return {"ln": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
            "slstm": xl.slstm_init(rng, _xlstm_cfg(cfg), cfg.param_dtype)}


def _stack_init(init_fn, rng, n: int):
    return jax.vmap(init_fn)(jax.random.split(rng, n))


def init(cfg: ModelCfg, rng: jax.Array) -> Dict[str, Any]:
    ks = jax.random.split(rng, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_padded, cfg.d_model,
                            cfg.param_dtype),
        "final_norm": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[1], cfg.d_model, cfg.vocab_padded,
                                    cfg.param_dtype, scale=0.02)
    if cfg.rope_fraction == 0.0:
        params["pos_embed"] = embed_init(ks[2], cfg.max_seq, cfg.d_model,
                                         cfg.param_dtype)
    if cfg.n_prefix > 0:
        params["prefix_proj"] = dense_init(ks[3], cfg.d_frontend, cfg.d_model,
                                           cfg.param_dtype)
    for i, seg in enumerate(segments(cfg)):
        k = jax.random.fold_in(ks[4], i)
        if seg.kind == "dense":
            params[seg.name] = _stack_init(
                lambda r: _dense_block_init(r, cfg), k, seg.count)
        elif seg.kind == "moe":
            params[seg.name] = _stack_init(
                lambda r: _moe_block_init(r, cfg), k, seg.count)
        elif seg.kind in ("mamba",):
            params[seg.name] = _stack_init(
                lambda r: _mamba_block_init(r, cfg), k, seg.count)
        elif seg.kind == "mlstm":
            params[seg.name] = _stack_init(
                lambda r: _mlstm_block_init(r, cfg), k, seg.count)
        elif seg.kind == "zamba_group":
            params[seg.name] = _stack_init(
                lambda r: _stack_init(
                    lambda r2: _mamba_block_init(r2, cfg), r, seg.inner),
                k, seg.count)
        elif seg.kind == "xlstm_group":
            params[seg.name] = {
                "m": _stack_init(
                    lambda r: _stack_init(
                        lambda r2: _mlstm_block_init(r2, cfg), r,
                        seg.inner - 1),
                    k, seg.count),
                "s": _stack_init(
                    lambda r: _slstm_block_init(r, cfg),
                    jax.random.fold_in(k, 1), seg.count),
            }
        else:
            raise ValueError(seg.kind)
    if cfg.family == "hybrid":
        params["shared_attn"] = _stack_init(
            lambda r: _shared_attn_init(r, cfg), ks[5],
            max(cfg.n_shared_attn, 1))
    return params


# ================================================================ caches ==
def cache_init(cfg: ModelCfg, batch: int, cache_len: int,
               dtype=None) -> Dict[str, Any]:
    """Empty decode caches (what serve_step threads through)."""
    dtype = dtype or cfg.dtype
    out: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    mc = _mamba_cfg(cfg) if cfg.family == "hybrid" else None
    xc = _xlstm_cfg(cfg) if cfg.family == "xlstm" else None

    def kv(n):
        return {"k": jnp.zeros((n, batch, cache_len, cfg.n_kv_heads,
                                cfg.head_dim), dtype),
                "v": jnp.zeros((n, batch, cache_len, cfg.n_kv_heads,
                                cfg.head_dim), dtype)}

    def mamba_state(shape_prefix):
        return {"h": jnp.zeros(shape_prefix + (batch, mc.n_heads, mc.d_state,
                                               mc.head_dim), dtype),
                "conv": jnp.zeros(shape_prefix + (batch, mc.conv_width - 1,
                                                  mc.d_inner + 2 * mc.d_state),
                                  dtype)}

    def mlstm_state(shape_prefix):
        nh, hd = xc.n_heads, xc.head_dim_m
        return {"C": jnp.zeros(shape_prefix + (batch, nh, hd, hd), dtype),
                "n": jnp.zeros(shape_prefix + (batch, nh, hd), dtype),
                "m": jnp.full(shape_prefix + (batch, nh), -1e30, jnp.float32),
                "conv": jnp.zeros(shape_prefix + (batch, xc.conv_width - 1,
                                                  xc.d_inner_m), dtype)}

    for seg in segments(cfg):
        if seg.kind in ("dense", "moe"):
            out[seg.name] = kv(seg.count)
        elif seg.kind == "mamba":
            out[seg.name] = mamba_state((seg.count,))
        elif seg.kind == "mlstm":
            out[seg.name] = mlstm_state((seg.count,))
        elif seg.kind == "zamba_group":
            out[seg.name] = mamba_state((seg.count, seg.inner))
            out[seg.name + "_attn"] = kv(seg.count)
        elif seg.kind == "xlstm_group":
            nh, hd = xc.n_heads, xc.head_dim_s
            out[seg.name] = {
                "m": mlstm_state((seg.count, seg.inner - 1)),
                "s": {"c": jnp.zeros((seg.count, batch, nh, hd), dtype),
                      "n": jnp.zeros((seg.count, batch, nh, hd), dtype),
                      "h": jnp.zeros((seg.count, batch, nh, hd), dtype),
                      "m": jnp.full((seg.count, batch, nh, hd), -1e30,
                                    jnp.float32)}}
    return out


# ============================================================ block apply ==
def _attn_kwargs(cfg: ModelCfg, window: Optional[int]):
    return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.head_dim, window=window,
                rope_fraction=cfg.rope_fraction, rope_theta=cfg.rope_theta)


def _dense_block(cfg, p, x, window):
    h = norm_apply(cfg.norm, p["ln1"], x)
    x = x + attn.attn_train(p["attn"], h, causal=True,
                            **_attn_kwargs(cfg, window))
    h = norm_apply(cfg.norm, p["ln2"], x)
    return x + mlp(p["mlp"], h, cfg.act)


def _dense_block_prefill(cfg, p, x, window, cache_len):
    h = norm_apply(cfg.norm, p["ln1"], x)
    a, kvc = attn.attn_prefill(p["attn"], h, cache_len=cache_len,
                               **_attn_kwargs(cfg, window))
    x = x + a
    h = norm_apply(cfg.norm, p["ln2"], x)
    return x + mlp(p["mlp"], h, cfg.act), kvc


def _dense_block_decode(cfg, p, x, kvc, pos, window):
    h = norm_apply(cfg.norm, p["ln1"], x)
    a, kvc = attn.attn_decode(p["attn"], h, kvc, pos,
                              **_attn_kwargs(cfg, window))
    x = x + a
    h = norm_apply(cfg.norm, p["ln2"], x)
    return x + mlp(p["mlp"], h, cfg.act), kvc


def _moe_ffn(cfg, p, h):
    return moe_mod.moe_apply(p["moe"], h, cfg.top_k, cfg.moe_impl,
                             cfg.capacity_factor)


def _moe_block(cfg, p, x, window):
    h = norm_apply(cfg.norm, p["ln1"], x)
    x = x + attn.attn_train(p["attn"], h, causal=True,
                            **_attn_kwargs(cfg, window))
    h = norm_apply(cfg.norm, p["ln2"], x)
    y, aux = _moe_ffn(cfg, p, h)
    return x + y, aux


def _moe_block_prefill(cfg, p, x, window, cache_len):
    h = norm_apply(cfg.norm, p["ln1"], x)
    a, kvc = attn.attn_prefill(p["attn"], h, cache_len=cache_len,
                               **_attn_kwargs(cfg, window))
    x = x + a
    h = norm_apply(cfg.norm, p["ln2"], x)
    y, aux = _moe_ffn(cfg, p, h)
    return x + y, aux, kvc


def _moe_block_decode(cfg, p, x, kvc, pos, window):
    h = norm_apply(cfg.norm, p["ln1"], x)
    a, kvc = attn.attn_decode(p["attn"], h, kvc, pos,
                              **_attn_kwargs(cfg, window))
    x = x + a
    h = norm_apply(cfg.norm, p["ln2"], x)
    y, _ = _moe_ffn(cfg, p, h)
    return x + y, kvc


def _mamba_block(cfg, p, x):
    return x + ssm.mamba_train(p["mamba"],
                               norm_apply(cfg.norm, p["ln"], x),
                               _mamba_cfg(cfg))


def _mamba_block_prefill(cfg, p, x):
    mc = _mamba_cfg(cfg)
    h = norm_apply(cfg.norm, p["ln"], x)
    y, st = ssm.mamba_prefill(p["mamba"], h, mc)
    return x + y, st


def _mamba_block_decode(cfg, p, x_t, st, _pos):
    mc = _mamba_cfg(cfg)
    h = norm_apply(cfg.norm, p["ln"], x_t)
    y, st = ssm.mamba_decode_step(p["mamba"],
                                  h, ssm.MambaState(st["h"], st["conv"]), mc)
    return x_t + y, {"h": st.h, "conv": st.conv}


def _mlstm_block(cfg, p, x):
    return x + xl.mlstm_block(p["mlstm"],
                              norm_apply(cfg.norm, p["ln"], x),
                              _xlstm_cfg(cfg))


def _mlstm_block_prefill(cfg, p, x):
    h = norm_apply(cfg.norm, p["ln"], x)
    y, st = xl.mlstm_prefill(p["mlstm"], h, _xlstm_cfg(cfg))
    return x + y, st


def _mlstm_block_decode(cfg, p, x_t, st, _pos):
    h = norm_apply(cfg.norm, p["ln"], x_t)
    y, st2 = xl.mlstm_decode_step(
        p["mlstm"], h, xl.MLSTMState(st["C"], st["n"], st["m"], st["conv"]),
        _xlstm_cfg(cfg))
    return x_t + y, {"C": st2.C, "n": st2.n, "m": st2.m, "conv": st2.conv}


def _slstm_block(cfg, p, x):
    return x + xl.slstm_block(p["slstm"],
                              norm_apply(cfg.norm, p["ln"], x),
                              _xlstm_cfg(cfg))


def _slstm_block_decode(cfg, p, x_t, st, _pos):
    h = norm_apply(cfg.norm, p["ln"], x_t)
    y, st2 = xl.slstm_decode_step(
        p["slstm"], h, xl.SLSTMState(st["c"], st["n"], st["h"], st["m"]),
        _xlstm_cfg(cfg))
    return x_t + y, {"c": st2.c, "n": st2.n, "h": st2.h, "m": st2.m}


def _shared_mlp(cfg, sp, x):
    if "mlp" not in sp:
        return x
    h = norm_apply(cfg.norm, sp["ln2"], x)
    return x + mlp(sp["mlp"], h, cfg.act)


def _shared_attn_apply(cfg, sp, x, window):
    h = norm_apply(cfg.norm, sp["ln"], x)
    x = x + attn.attn_train(sp["attn"], h, causal=True,
                            **_attn_kwargs(cfg, window))
    return _shared_mlp(cfg, sp, x)


def _shared_attn_prefill(cfg, sp, x, window, cache_len):
    h = norm_apply(cfg.norm, sp["ln"], x)
    a, kvc = attn.attn_prefill(sp["attn"], h, cache_len=cache_len,
                               **_attn_kwargs(cfg, window))
    return _shared_mlp(cfg, sp, x + a), kvc


def _shared_attn_decode(cfg, sp, x, kvc, pos, window):
    h = norm_apply(cfg.norm, sp["ln"], x)
    a, kvc = attn.attn_decode(sp["attn"], h, kvc, pos,
                              **_attn_kwargs(cfg, window))
    return _shared_mlp(cfg, sp, x + a), kvc


# =============================================================== forward ==
def _pick(tree, idx):
    return jax.tree_util.tree_map(lambda a: a[idx], tree)


def _dyn_pick(tree, idx):
    return jax.tree_util.tree_map(
        lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0, keepdims=False),
        tree)


def _embed_tokens(cfg: ModelCfg, params, tokens, prefix_embeds, pos0=0):
    x = embed(params["embed"], tokens, cfg.dtype)
    if cfg.rope_fraction == 0.0:
        S = tokens.shape[1]
        pos = jnp.arange(pos0, pos0 + S)
        x = x + embed(params["pos_embed"], pos, cfg.dtype)[None]
    if cfg.n_prefix > 0:
        if prefix_embeds is None:
            raise ValueError(f"{cfg.name} requires prefix_embeds")
        pref = dense(params["prefix_proj"], prefix_embeds.astype(cfg.dtype))
        x = jnp.concatenate([pref, x], axis=1)
    return x


def forward(cfg: ModelCfg, params, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None,
            window: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits, aux_loss)."""
    window = window if window is not None else cfg.window
    x = _embed_tokens(cfg, params, tokens, prefix_embeds)
    x = constrain(x, ("batch", "act_seq", None))
    aux = jnp.zeros((), jnp.float32)
    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        ck = (lambda f: jax.checkpoint(f, policy=policy))
    else:
        ck = (lambda f: f)

    for seg in segments(cfg):
        p_seg = params[seg.name]
        if seg.kind == "dense":
            def body(h, pl):
                h = _dense_block(cfg, pl, h, window)
                return constrain(h, ("batch", "act_seq", None)), None
            x, _ = _scan(ck(body), x, p_seg)
        elif seg.kind == "moe":
            def body(h, pl):
                h, a = _moe_block(cfg, pl, h, window)
                return constrain(h, ("batch", "act_seq", None)), a
            x, auxs = _scan(ck(body), x, p_seg)
            aux = aux + jnp.sum(auxs)
        elif seg.kind == "mamba":
            def body(h, pl):
                return _mamba_block(cfg, pl, h), None
            x, _ = _scan(ck(body), x, p_seg)
        elif seg.kind == "mlstm":
            def body(h, pl):
                return _mlstm_block(cfg, pl, h), None
            x, _ = _scan(ck(body), x, p_seg)
        elif seg.kind == "zamba_group":
            shared = params["shared_attn"]
            n_sh = max(cfg.n_shared_attn, 1)

            def group_body(carry, pl_g):
                h, g = carry

                def inner(h2, pl):
                    return _mamba_block(cfg, pl, h2), None
                h, _ = _scan(inner, h, pl_g)
                sp = _dyn_pick(shared, g % n_sh)
                h = _shared_attn_apply(cfg, sp, h, window)
                return (constrain(h, ("batch", "act_seq", None)), g + 1), None
            (x, _), _ = _scan(ck(group_body), (x, jnp.int32(0)), p_seg)
        elif seg.kind == "xlstm_group":
            def group_body(h, pl_g):
                def inner(h2, pl):
                    return _mlstm_block(cfg, pl, h2), None
                h, _ = _scan(inner, h, pl_g["m"])
                h = _slstm_block(cfg, pl_g["s"], h)
                return constrain(h, ("batch", "act_seq", None)), None
            x, _ = _scan(ck(group_body), x, p_seg)
        else:
            raise ValueError(seg.kind)

    x = norm_apply(cfg.norm, params["final_norm"], x)
    logits = _head(cfg, params, x)
    return constrain(logits, ("batch", None, "vocab")), aux


def _head(cfg, params, x):
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].astype(x.dtype).T
    else:
        logits = dense(params["head"], x)
    if cfg.vocab_padded != cfg.vocab:
        # mask padding classes so the softmax is over the true vocabulary
        valid = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(valid, logits, -1e30)
    return logits


def train_loss(cfg: ModelCfg, params, batch: Dict[str, jax.Array]
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(cfg, params, batch["tokens"],
                          batch.get("prefix_embeds"))
    if cfg.n_prefix > 0:
        logits = logits[:, cfg.n_prefix:, :]
    ce = cross_entropy(logits, batch["labels"])
    loss = ce + cfg.aux_loss_weight * aux
    return loss, {"ce": ce, "aux": aux}


# =============================================================== prefill ==
def prefill(cfg: ModelCfg, params, tokens: jax.Array,
            cache_len: Optional[int] = None,
            prefix_embeds: Optional[jax.Array] = None,
            window: Optional[int] = None
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Forward over the prompt, returning (last-position logits, cache)."""
    window = window if window is not None else cfg.window
    x = _embed_tokens(cfg, params, tokens, prefix_embeds)
    x = constrain(x, ("batch", None, None))
    B, S = x.shape[:2]
    cache_len = cache_len or S
    cache: Dict[str, Any] = {"pos": jnp.asarray(S, jnp.int32)}

    for seg in segments(cfg):
        p_seg = params[seg.name]
        if seg.kind in ("dense", "moe"):
            def body(h, pl):
                if seg.kind == "dense":
                    h2, kvc = _dense_block_prefill(cfg, pl, h, window,
                                                   cache_len)
                else:
                    h2, _, kvc = _moe_block_prefill(cfg, pl, h, window,
                                                    cache_len)
                return constrain(h2, ("batch", None, None)), \
                    {"k": kvc.k, "v": kvc.v}
            x, kvs = _scan(body, x, p_seg)
            cache[seg.name] = kvs
        elif seg.kind == "mamba":
            def body(h, pl):
                h2, st = _mamba_block_prefill(cfg, pl, h)
                return h2, {"h": st.h, "conv": st.conv}
            x, sts = _scan(body, x, p_seg)
            cache[seg.name] = sts
        elif seg.kind == "mlstm":
            def body(h, pl):
                h2, st = _mlstm_block_prefill(cfg, pl, h)
                return h2, {"C": st.C, "n": st.n, "m": st.m, "conv": st.conv}
            x, sts = _scan(body, x, p_seg)
            cache[seg.name] = sts
        elif seg.kind == "zamba_group":
            shared = params["shared_attn"]
            n_sh = max(cfg.n_shared_attn, 1)

            def group_body(carry, pl_g):
                h, g = carry

                def inner(h2, pl):
                    h3, st = _mamba_block_prefill(cfg, pl, h2)
                    return h3, {"h": st.h, "conv": st.conv}
                h, sts = _scan(inner, h, pl_g)
                sp = _dyn_pick(shared, g % n_sh)
                h, kvc = _shared_attn_prefill(cfg, sp, h, window, cache_len)
                return (constrain(h, ("batch", None, None)), g + 1), \
                    (sts, {"k": kvc.k, "v": kvc.v})
            (x, _), (sts, kvs) = _scan(group_body, (x, jnp.int32(0)),
                                              p_seg)
            cache[seg.name] = sts
            cache[seg.name + "_attn"] = kvs
        elif seg.kind == "xlstm_group":
            def group_body(h, pl_g):
                def inner(h2, pl):
                    h3, st = _mlstm_block_prefill(cfg, pl, h2)
                    return h3, {"C": st.C, "n": st.n, "m": st.m,
                                "conv": st.conv}
                h, msts = _scan(inner, h, pl_g["m"])
                hh = norm_apply(cfg.norm, pl_g["s"]["ln"], h)
                y, sst = xl.slstm_seq(pl_g["s"]["slstm"], hh, _xlstm_cfg(cfg))
                # FFN part of the sLSTM block
                y2 = xl.slstm_block_ffn(pl_g["s"]["slstm"], y)
                h = h + y2
                return constrain(h, ("batch", None, None)), \
                    (msts, {"c": sst.c, "n": sst.n, "h": sst.h, "m": sst.m})
            x, (msts, ssts) = _scan(group_body, x, p_seg)
            cache[seg.name] = {"m": msts, "s": ssts}
        else:
            raise ValueError(seg.kind)

    x = norm_apply(cfg.norm, params["final_norm"], x[:, -1:, :])
    logits = _head(cfg, params, x)
    return logits, cache


# ================================================================ decode ==
def decode_step(cfg: ModelCfg, params, cache: Dict[str, Any],
                tokens: jax.Array, window: Optional[int] = None
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step.  tokens: (B, 1) int32; cache from
    :func:`cache_init`/:func:`prefill`.  Returns (logits (B,1,V), cache)."""
    window = window if window is not None else cfg.window
    pos = cache["pos"]
    x = embed(params["embed"], tokens, cfg.dtype)
    if cfg.rope_fraction == 0.0:
        x = x + embed(params["pos_embed"], pos[None], cfg.dtype)[None]
    x = constrain(x, ("batch", None, None))
    new_cache: Dict[str, Any] = {"pos": pos + 1}

    for seg in segments(cfg):
        p_seg = params[seg.name]
        if seg.kind in ("dense", "moe"):
            def body(h, xs):
                pl, c = xs
                kvc = attn.KVCache(c["k"], c["v"])
                if seg.kind == "dense":
                    h2, kvc = _dense_block_decode(cfg, pl, h, kvc, pos, window)
                else:
                    h2, kvc = _moe_block_decode(cfg, pl, h, kvc, pos, window)
                return h2, {"k": kvc.k, "v": kvc.v}
            x, kvs = _scan(body, x, (p_seg, cache[seg.name]))
            new_cache[seg.name] = kvs
        elif seg.kind == "mamba":
            def body(h, xs):
                pl, c = xs
                h1 = h[:, 0, :]
                h2, c2 = _mamba_block_decode(cfg, pl, h1, c, pos)
                return h2[:, None, :], c2
            x, sts = _scan(body, x, (p_seg, cache[seg.name]))
            new_cache[seg.name] = sts
        elif seg.kind == "mlstm":
            def body(h, xs):
                pl, c = xs
                h2, c2 = _mlstm_block_decode(cfg, pl, h[:, 0, :], c, pos)
                return h2[:, None, :], c2
            x, sts = _scan(body, x, (p_seg, cache[seg.name]))
            new_cache[seg.name] = sts
        elif seg.kind == "zamba_group":
            shared = params["shared_attn"]
            n_sh = max(cfg.n_shared_attn, 1)

            def group_body(carry, xs):
                h, g = carry
                pl_g, c_g, ckv = xs

                def inner(h2, xs2):
                    pl, c = xs2
                    h3, c2 = _mamba_block_decode(cfg, pl, h2[:, 0, :], c, pos)
                    return h3[:, None, :], c2
                h, sts = _scan(inner, h, (pl_g, c_g))
                sp = _dyn_pick(shared, g % n_sh)
                h, kvc = _shared_attn_decode(
                    cfg, sp, h, attn.KVCache(ckv["k"], ckv["v"]), pos, window)
                return (h, g + 1), (sts, {"k": kvc.k, "v": kvc.v})
            (x, _), (sts, kvs) = _scan(
                group_body, (x, jnp.int32(0)),
                (p_seg, cache[seg.name], cache[seg.name + "_attn"]))
            new_cache[seg.name] = sts
            new_cache[seg.name + "_attn"] = kvs
        elif seg.kind == "xlstm_group":
            def group_body(h, xs):
                pl_g, c_g = xs

                def inner(h2, xs2):
                    pl, c = xs2
                    h3, c2 = _mlstm_block_decode(cfg, pl, h2[:, 0, :], c, pos)
                    return h3[:, None, :], c2
                h, msts = _scan(inner, h, (pl_g["m"], c_g["m"]))
                h1, s2 = _slstm_block_decode(cfg, pl_g["s"], h[:, 0, :],
                                             c_g["s"], pos)
                return h1[:, None, :], (msts, s2)
            x, (msts, ssts) = _scan(group_body, x,
                                           (p_seg, cache[seg.name]))
            new_cache[seg.name] = {"m": msts, "s": ssts}
        else:
            raise ValueError(seg.kind)

    x = norm_apply(cfg.norm, params["final_norm"], x)
    logits = _head(cfg, params, x)
    return logits, new_cache


# ============================================================== analytics ==
def count_params(cfg: ModelCfg) -> int:
    """Analytic parameter count (cross-checked against init in tests)."""
    d, V = cfg.d_model, cfg.vocab_padded
    total = V * d                                 # embed
    if not cfg.tie_embeddings:
        total += d * V                            # head
    if cfg.rope_fraction == 0.0:
        total += cfg.max_seq * d
    if cfg.n_prefix > 0:
        total += cfg.d_frontend * d
    nrm = 2 * d if cfg.norm == "layernorm" else d
    total += nrm                                  # final norm

    def attn_params():
        return d * cfg.n_heads * cfg.head_dim * 2 \
            + d * cfg.n_kv_heads * cfg.head_dim * 2 \
            + (cfg.n_heads * cfg.head_dim + 2 * cfg.n_kv_heads * cfg.head_dim
               if cfg.qkv_bias else 0)

    def mlp_params(d_ff):
        mults = 3 if cfg.act in ("silu", "swiglu") else 2
        return d * d_ff * mults

    def mamba_params():
        mc = _mamba_cfg(cfg)
        di, ds, nh = mc.d_inner, mc.d_state, mc.n_heads
        return (d * (2 * di + 2 * ds + nh)            # in_proj
                + (di + 2 * ds) * (mc.conv_width + 1)  # conv w + b
                + 3 * nh + di                          # A_log, D, dt_bias, norm
                + di * d)                              # out_proj

    def mlstm_params():
        c = _xlstm_cfg(cfg)
        di, hd = c.d_inner_m, c.head_dim_m
        return (d * 2 * di + di * (c.conv_width + 1)
                + 3 * cfg.n_heads * hd * hd
                + di * 2 * cfg.n_heads + di + di * d + cfg.n_heads)

    def slstm_params():
        c = _xlstm_cfg(cfg)
        hd = c.head_dim_s
        d_ff = xl._slstm_ffn_width(c)
        return (d * 4 * d + 4 * cfg.n_heads * hd * hd + 4 * d + 2 * d
                + d * d_ff * 2 + d_ff * d + cfg.n_heads * hd)

    for seg in segments(cfg):
        if seg.kind == "dense":
            total += seg.count * (attn_params() + mlp_params(cfg.d_ff)
                                  + 2 * nrm)
        elif seg.kind == "moe":
            per = attn_params() + 2 * nrm + d * cfg.n_experts \
                + cfg.n_experts * d * cfg.d_ff * 3
            if cfg.n_shared_experts:
                per += d * (cfg.n_shared_experts * cfg.d_ff) * 3
            total += seg.count * per
        elif seg.kind == "mamba":
            total += seg.count * (mamba_params() + nrm)
        elif seg.kind == "mlstm":
            total += seg.count * (mlstm_params() + nrm)
        elif seg.kind == "zamba_group":
            total += seg.count * seg.inner * (mamba_params() + nrm)
        elif seg.kind == "xlstm_group":
            total += seg.count * ((seg.inner - 1) * (mlstm_params() + nrm)
                                  + slstm_params() + nrm)
    if cfg.family == "hybrid":
        per_shared = attn_params() + nrm
        if cfg.d_ff > 0:
            per_shared += mlp_params(cfg.d_ff) + nrm
        total += max(cfg.n_shared_attn, 1) * per_shared
    return int(total)
