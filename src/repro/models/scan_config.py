"""Global scan-unroll switch.

XLA's cost analysis counts a while-loop body once regardless of trip count,
so the roofline harness can either (a) correct per-segment analytically
(repro.analysis.hlo trip-count weighting) or (b) lower with scans unrolled
and read exact numbers.  ``set_unroll`` flips (b) on for a ``with`` scope.
Default is 1 (rolled scans — fast compiles for the dry-run gate).
"""
from __future__ import annotations

import contextlib

import jax

_UNROLL = [1]


def scan(body, init, xs, **kw):
    unroll = kw.pop("unroll", None)
    if unroll is None:
        unroll = _UNROLL[0]
    if unroll is True or (isinstance(unroll, int) and unroll != 1):
        kw["unroll"] = unroll
    return jax.lax.scan(body, init, xs, **kw)


@contextlib.contextmanager
def set_unroll(n):
    """n=True -> fully unroll every model scan (exact cost analysis)."""
    prev = _UNROLL[0]
    _UNROLL[0] = n
    try:
        yield
    finally:
        _UNROLL[0] = prev
