"""Grouped-query attention with train / prefill / decode paths.

One implementation serves every attention-bearing architecture:
* GQA with arbitrary (n_heads, n_kv_heads) — MHA when equal;
* causal or bidirectional masking;
* optional sliding window (Mixtral / long-context dense variants);
* KV cache for prefill (fill) and decode (single-token append);
* cross-attention (keys/values from encoder memory).

Layout conventions: activations (B, S, d); q/k/v (B, S, H, hd); KV cache
(B, S_max, H_kv, hd).  Scores run in fp32.  The decode path writes the cache
at ``pos`` via dynamic_update_slice (donated in serve_step).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from .layers import apply_rope, dense, dense_init

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array        # (B, S_max, H_kv, hd)
    v: jax.Array        # (B, S_max, H_kv, hd)


def attn_init(rng: jax.Array, d: int, n_heads: int, n_kv: int, head_dim: int,
              dtype=jnp.float32, qkv_bias: bool = False) -> Dict[str, Any]:
    kq, kk, kv, ko = jax.random.split(rng, 4)
    p = {"wq": dense_init(kq, d, n_heads * head_dim, dtype),
         "wk": dense_init(kk, d, n_kv * head_dim, dtype),
         "wv": dense_init(kv, d, n_kv * head_dim, dtype),
         "wo": dense_init(ko, n_heads * head_dim, d, dtype,
                          scale=1.0 / math.sqrt(n_heads * head_dim))}
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def _project_qkv(p, x_q, x_kv, n_heads, n_kv, head_dim):
    B, Sq = x_q.shape[:2]
    Skv = x_kv.shape[1]
    q = dense({"w": p["wq"]["w"]}, x_q)
    k = dense({"w": p["wk"]["w"]}, x_kv)
    v = dense({"w": p["wv"]["w"]}, x_kv)
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, Sq, n_heads, head_dim),
            k.reshape(B, Skv, n_kv, head_dim),
            v.reshape(B, Skv, n_kv, head_dim))


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
         mask: Optional[jax.Array], scale: Optional[float] = None) -> jax.Array:
    """Grouped-query attention without materializing expanded KV.

    q: (B,Sq,H,hd); k/v: (B,Skv,Hkv,hd) with H = g·Hkv; mask broadcastable
    to (B,1/H,Sq,Skv) (True = attend).  The query heads are reshaped into
    (Hkv, g) groups and contracted against the *unexpanded* KV — a
    ``jnp.repeat`` expansion costs rep× KV memory and forces GSPMD to
    rematerialize sharded caches (measured 2 GiB all-gather per decode
    layer)."""
    B, Sq, H, hd = q.shape
    hkv = k.shape[2]
    g = H // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, hkv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        # mask comes in as (B,1,Sq,Skv)-ish; insert the group axis
        m = jnp.expand_dims(mask, 2) if mask.ndim == 4 else mask
        scores = jnp.where(m, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, hd)


def make_mask(Sq: int, Skv: int, causal: bool, window: Optional[int],
              q_offset: int = 0) -> Optional[jax.Array]:
    """(1,1,Sq,Skv) boolean mask.  ``q_offset`` shifts query positions (for
    prefill continuation); ``window`` keeps keys within [pos-window+1, pos]."""
    if not causal and window is None:
        return None
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Skv)
    rel = qpos[:, None] - kpos[None, :]
    m = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        m &= rel >= 0
    if window is not None:
        m &= rel < window
    return m[None, None]


def attn_train(p, x: jax.Array, *, n_heads: int, n_kv: int, head_dim: int,
               causal: bool = True, window: Optional[int] = None,
               rope_fraction: float = 1.0, rope_theta: float = 10_000.0,
               x_kv: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence attention (training / encoder).  ``x_kv`` switches to
    cross-attention (no RoPE on keys of encoder memory by convention here —
    both sides get positions of their own sequence)."""
    src = x if x_kv is None else x_kv
    q, k, v = _project_qkv(p, x, src, n_heads, n_kv, head_dim)
    if rope_fraction > 0:
        qpos = jnp.arange(x.shape[1])[None]
        kpos = jnp.arange(src.shape[1])[None]
        q = apply_rope(q, qpos, rope_fraction, rope_theta)
        k = apply_rope(k, kpos, rope_fraction, rope_theta)
    mask = make_mask(x.shape[1], src.shape[1],
                     causal and x_kv is None, window)
    out = sdpa(q, k, v, mask)
    B, S = x.shape[:2]
    return dense({"w": p["wo"]["w"]}, out.reshape(B, S, n_heads * head_dim))


def attn_prefill(p, x: jax.Array, *, n_heads: int, n_kv: int, head_dim: int,
                 cache_len: int, window: Optional[int] = None,
                 rope_fraction: float = 1.0, rope_theta: float = 10_000.0
                 ) -> Tuple[jax.Array, KVCache]:
    """Causal attention over the prompt, emitting a KV cache of cache_len
    (>= S; right-padded)."""
    B, S = x.shape[:2]
    q, k, v = _project_qkv(p, x, x, n_heads, n_kv, head_dim)
    if rope_fraction > 0:
        pos = jnp.arange(S)[None]
        q = apply_rope(q, pos, rope_fraction, rope_theta)
        k = apply_rope(k, pos, rope_fraction, rope_theta)
    mask = make_mask(S, S, True, window)
    out = sdpa(q, k, v, mask)
    pad = [(0, 0), (0, cache_len - S), (0, 0), (0, 0)]
    cache = KVCache(k=jnp.pad(k, pad), v=jnp.pad(v, pad))
    return (dense({"w": p["wo"]["w"]}, out.reshape(B, S, n_heads * head_dim)),
            cache)


def attn_decode(p, x: jax.Array, cache: KVCache, pos: jax.Array, *,
                n_heads: int, n_kv: int, head_dim: int,
                window: Optional[int] = None,
                rope_fraction: float = 1.0, rope_theta: float = 10_000.0
                ) -> Tuple[jax.Array, KVCache]:
    """One-token decode. x: (B, 1, d); ``pos`` scalar int32 — the index of
    this token; cache holds positions [0, pos)."""
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, x, n_heads, n_kv, head_dim)
    if rope_fraction > 0:
        pvec = jnp.full((1, 1), pos, dtype=jnp.int32)
        q = apply_rope(q, pvec, rope_fraction, rope_theta)
        k = apply_rope(k, pvec, rope_fraction, rope_theta)
    new_k = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                         (0, pos, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                         (0, pos, 0, 0))
    # Pin the decode layout: cache + query stay head_dim-sharded so the
    # score contraction psums a (B,H,1,S) f32 instead of GSPMD re-gathering
    # the whole cache (measured 1 GiB/layer without these).
    q = constrain(q, ("batch", None, None, "model"))
    new_k = constrain(new_k, ("batch", None, None, "model"))
    new_v = constrain(new_v, ("batch", None, None, "model"))
    S_max = new_k.shape[1]
    kpos = jnp.arange(S_max)
    valid = kpos <= pos
    if window is not None:
        valid &= kpos > pos - window
    mask = valid[None, None, None, :]      # (1,1,1,S_max)
    out = sdpa(q, new_k.astype(q.dtype), new_v.astype(q.dtype), mask)
    y = dense({"w": p["wo"]["w"]}, out.reshape(B, 1, n_heads * head_dim))
    return y, KVCache(k=new_k, v=new_v)


def attn_flops(tokens: int, kv_tokens: int, d: int, n_heads: int, n_kv: int,
               head_dim: int) -> float:
    """Forward FLOPs: projections + scores + value mix."""
    proj = 2.0 * tokens * d * (n_heads * head_dim) \
        + 2.0 * 2.0 * kv_tokens * d * (n_kv * head_dim) \
        + 2.0 * tokens * (n_heads * head_dim) * d
    scores = 2.0 * 2.0 * tokens * kv_tokens * n_heads * head_dim
    return proj + scores
