"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential scan).

mLSTM training uses the stabilized parallel (quadratic) form — an
attention-like matmul with an input/forget-gate decay matrix D — so it maps
onto the MXU; decode uses the O(1) recurrence

    C_t = f_t C_{t-1} + i_t v_t k_t^T ,  n_t = f_t n_{t-1} + i_t k_t
    h_t = C_t q_t / max(|n_t^T q_t|, exp(-m_t))

with log-space gate stabilization m_t.  sLSTM is inherently sequential
(recurrent hidden feedback) and runs under ``lax.scan`` in both modes.

Block wrappers follow the paper: mLSTM = pre-up-projection (×2) block;
sLSTM = post-up-projection block with a gated FFN (×4/3).  ``d_ff = 0`` in
the assigned xlstm-1.3b config means exactly this: FFN capacity lives inside
the blocks.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import causal_conv1d, causal_conv1d_init, causal_conv1d_step, \
    dense_init, layernorm, layernorm_init, rmsnorm, rmsnorm_init


class XLSTMCfg(NamedTuple):
    d_model: int
    n_heads: int
    proj_factor_m: float = 2.0     # mLSTM pre-up-projection
    proj_factor_s: float = 4.0 / 3.0  # sLSTM FFN
    conv_width: int = 4

    @property
    def d_inner_m(self) -> int:
        return int(self.d_model * self.proj_factor_m)

    @property
    def head_dim_m(self) -> int:
        return self.d_inner_m // self.n_heads

    @property
    def head_dim_s(self) -> int:
        return self.d_model // self.n_heads


# ------------------------------------------------------------------ mLSTM --
class MLSTMState(NamedTuple):
    C: jax.Array    # (B, nh, hd, hd)
    n: jax.Array    # (B, nh, hd)
    m: jax.Array    # (B, nh)
    conv: jax.Array  # (B, W-1, d_inner)


def mlstm_init(rng: jax.Array, cfg: XLSTMCfg, dtype=jnp.float32) -> Dict[str, Any]:
    d, di, nh = cfg.d_model, cfg.d_inner_m, cfg.n_heads
    hd = cfg.head_dim_m
    ks = jax.random.split(rng, 8)

    def blockdiag(key):
        # per-head (block-diagonal) projection, as in the official mLSTM
        return (jax.random.normal(key, (nh, hd, hd))
                / math.sqrt(hd)).astype(dtype)

    return {
        "up": dense_init(ks[0], d, 2 * di, dtype),       # (x_m, z)
        "conv": causal_conv1d_init(ks[1], di, cfg.conv_width, dtype),
        "wq": blockdiag(ks[2]),
        "wk": blockdiag(ks[3]),
        "wv": blockdiag(ks[4]),
        "w_if": dense_init(ks[5], di, 2 * nh, dtype),    # i,f gate pre-acts
        "norm": rmsnorm_init(di, dtype),
        "down": dense_init(ks[6], di, d, dtype, scale=1.0 / math.sqrt(di)),
        "f_bias": jnp.full((nh,), 3.0, jnp.float32),     # open forget gates
    }


def _mlstm_parallel(q, k, v, i_pre, f_pre):
    """q/k/v: (B,S,nh,hd); i_pre/f_pre: (B,S,nh).  Stabilized parallel form."""
    B, S, nh, hd = q.shape
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))        # (B,S,nh)
    cum = jnp.cumsum(logf, axis=1)
    # D_log[l,m] = cum_l - cum_m + i_m  (contribution of step m to step l)
    D_log = (cum[:, :, None, :] - cum[:, None, :, :]
             + i_pre.astype(jnp.float32)[:, None, :, :])        # (B,Sq,Sk,nh)
    causal = jnp.tril(jnp.ones((S, S), bool))[None, :, :, None]
    D_log = jnp.where(causal, D_log, -jnp.inf)
    m_row = jnp.max(D_log, axis=2, keepdims=True)               # (B,S,1,nh)
    m_row = jnp.maximum(m_row, -1e30)
    D = jnp.exp(D_log - m_row)
    scores = jnp.einsum("blhd,bmhd->blmh", q, k) / math.sqrt(hd)
    w = scores.astype(jnp.float32) * D
    denom = jnp.maximum(jnp.abs(w.sum(axis=2)),
                        jnp.exp(-m_row[:, :, 0, :]))            # (B,S,nh)
    y = jnp.einsum("blmh,bmhd->blhd", w.astype(v.dtype), v)
    return y / denom[..., None].astype(v.dtype)


def mlstm_block(p, x: jax.Array, cfg: XLSTMCfg) -> jax.Array:
    """Full-sequence mLSTM block body (caller owns residual)."""
    B, S, _ = x.shape
    di, nh, hd = cfg.d_inner_m, cfg.n_heads, cfg.head_dim_m
    up = x @ p["up"]["w"].astype(x.dtype)
    xm, z = jnp.split(up, 2, axis=-1)
    xc = jax.nn.silu(causal_conv1d(p["conv"], xm))
    xch = xc.reshape(B, S, nh, hd)
    q = jnp.einsum("bsnd,nde->bsne", xch, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsnd,nde->bsne", xch, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsnd,nde->bsne", xm.reshape(B, S, nh, hd),
                   p["wv"].astype(x.dtype))
    if_pre = xc @ p["w_if"]["w"].astype(x.dtype)                # (B,S,2nh)
    i_pre, f_pre = jnp.split(if_pre, 2, axis=-1)
    f_pre = f_pre + p["f_bias"][None, None, :].astype(f_pre.dtype)
    y = _mlstm_parallel(q, k, v, i_pre, f_pre).reshape(B, S, di)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z)
    return y @ p["down"]["w"].astype(x.dtype)


def mlstm_prefill(p, x: jax.Array, cfg: XLSTMCfg
                  ) -> Tuple[jax.Array, MLSTMState]:
    """Parallel-form forward that also emits the recurrent state after the
    last position (matches the decode recurrence exactly: the running
    stabilizer m_t = max_{m≤t}(Σ_{j>m} log f_j + ĩ_m))."""
    B, S, _ = x.shape
    di, nh, hd = cfg.d_inner_m, cfg.n_heads, cfg.head_dim_m
    up = x @ p["up"]["w"].astype(x.dtype)
    xm, z = jnp.split(up, 2, axis=-1)
    conv_tail = xm[:, S - (cfg.conv_width - 1):, :]
    xc = jax.nn.silu(causal_conv1d(p["conv"], xm))
    xch = xc.reshape(B, S, nh, hd)
    q = jnp.einsum("bsnd,nde->bsne", xch, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsnd,nde->bsne", xch, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsnd,nde->bsne", xm.reshape(B, S, nh, hd),
                   p["wv"].astype(x.dtype))
    i_pre, f_pre = jnp.split(xc @ p["w_if"]["w"].astype(x.dtype), 2, axis=-1)
    f_pre = f_pre + p["f_bias"][None, None, :].astype(f_pre.dtype)
    y = _mlstm_parallel(q, k, v, i_pre, f_pre).reshape(B, S, di)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z)
    out = y @ p["down"]["w"].astype(x.dtype)

    # final state: C̃_S = Σ_m exp(cum_S - cum_m + i_m - m_S) v_m k_m^T
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    cum = jnp.cumsum(logf, axis=1)                       # (B,S,nh)
    w_log = cum[:, -1:, :] - cum + i_pre.astype(jnp.float32)  # (B,S,nh)
    m_S = jnp.max(w_log, axis=1)                          # (B,nh)
    w = jnp.exp(w_log - m_S[:, None, :]).astype(x.dtype)  # (B,S,nh)
    C = jnp.einsum("bsh,bshv,bshk->bhvk", w, v, k)
    n = jnp.einsum("bsh,bshk->bhk", w, k)
    return out, MLSTMState(C=C, n=n, m=m_S, conv=conv_tail)


def mlstm_state_init(cfg: XLSTMCfg, batch: int, dtype=jnp.float32) -> MLSTMState:
    nh, hd = cfg.n_heads, cfg.head_dim_m
    return MLSTMState(
        C=jnp.zeros((batch, nh, hd, hd), dtype),
        n=jnp.zeros((batch, nh, hd), dtype),
        m=jnp.full((batch, nh), -1e30, jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner_m), dtype))


def mlstm_decode_step(p, x_t: jax.Array, state: MLSTMState, cfg: XLSTMCfg
                      ) -> Tuple[jax.Array, MLSTMState]:
    """x_t: (B, d_model)."""
    B = x_t.shape[0]
    di, nh, hd = cfg.d_inner_m, cfg.n_heads, cfg.head_dim_m
    up = x_t @ p["up"]["w"].astype(x_t.dtype)
    xm, z = jnp.split(up, 2, axis=-1)
    xc, new_conv = causal_conv1d_step(p["conv"], xm, state.conv)
    xc = jax.nn.silu(xc)
    xch = xc.reshape(B, nh, hd)
    q = jnp.einsum("bnd,nde->bne", xch, p["wq"].astype(x_t.dtype))
    k = jnp.einsum("bnd,nde->bne", xch, p["wk"].astype(x_t.dtype))
    v = jnp.einsum("bnd,nde->bne", xm.reshape(B, nh, hd),
                   p["wv"].astype(x_t.dtype))
    i_pre, f_pre = jnp.split(xc @ p["w_if"]["w"].astype(x_t.dtype), 2, axis=-1)
    f_pre = (f_pre + p["f_bias"][None, :]).astype(jnp.float32)
    i_pre = i_pre.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_pre)                            # (B,nh)
    m_new = jnp.maximum(logf + state.m, i_pre)
    fs = jnp.exp(logf + state.m - m_new).astype(x_t.dtype)[..., None]
    is_ = jnp.exp(i_pre - m_new).astype(x_t.dtype)[..., None]
    C = fs[..., None] * state.C + is_[..., None] * v[..., :, None] * k[..., None, :]
    n = fs * state.n + is_ * k
    num = jnp.einsum("bhvk,bhk->bhv", C, q / math.sqrt(hd))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q / math.sqrt(hd))),
                      jnp.exp(-m_new).astype(x_t.dtype))
    y = (num / den[..., None]).reshape(B, di)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z)
    return y @ p["down"]["w"].astype(x_t.dtype), \
        MLSTMState(C=C, n=n, m=m_new, conv=new_conv)


# ------------------------------------------------------------------ sLSTM --
class SLSTMState(NamedTuple):
    c: jax.Array    # (B, nh, hd)
    n: jax.Array    # (B, nh, hd)
    h: jax.Array    # (B, nh, hd)
    m: jax.Array    # (B, nh, hd)


def _slstm_ffn_width(cfg: XLSTMCfg) -> int:
    """×4/3 gated FFN, rounded up to a multiple of 64 (official xLSTM does
    the same; also keeps the dim divisible by the 16-wide model axis)."""
    raw = int(cfg.proj_factor_s * cfg.d_model)
    return -(-raw // 64) * 64


def slstm_init(rng: jax.Array, cfg: XLSTMCfg, dtype=jnp.float32) -> Dict[str, Any]:
    d, nh, hd = cfg.d_model, cfg.n_heads, cfg.head_dim_s
    d_ff = _slstm_ffn_width(cfg)
    ks = jax.random.split(rng, 6)
    s = 1.0 / math.sqrt(hd)
    return {
        # input projections for gates z,i,f,o : (d, 4*d)
        "w_in": dense_init(ks[0], d, 4 * d, dtype),
        # recurrent per-head block-diagonal: (4, nh, hd, hd)
        "r": (jax.random.normal(ks[1], (4, nh, hd, hd)) * s).astype(dtype),
        "b": jnp.zeros((4, d), dtype),
        "gn": layernorm_init(d, dtype),
        "ffn_gate": dense_init(ks[2], d, d_ff, dtype),
        "ffn_up": dense_init(ks[3], d, d_ff, dtype),
        "ffn_down": dense_init(ks[4], d_ff, d, dtype,
                               scale=1.0 / math.sqrt(d_ff)),
        "f_bias": jnp.full((nh, hd), 3.0, jnp.float32),
    }


def _slstm_cell(p, x_proj_t, state: SLSTMState, cfg: XLSTMCfg
                ) -> Tuple[jax.Array, SLSTMState]:
    """One sLSTM step.  x_proj_t: (B, 4, nh, hd) pre-activations from input."""
    nh, hd = cfg.n_heads, cfg.head_dim_s
    rec = jnp.einsum("bhd,ghde->bghe", state.h, p["r"].astype(state.h.dtype))
    pre = x_proj_t + rec + p["b"].astype(x_proj_t.dtype).reshape(4, nh, hd)[None]
    z_pre, i_pre, f_pre, o_pre = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    logi = i_pre.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(
        f_pre.astype(jnp.float32) + p["f_bias"][None])
    m_new = jnp.maximum(logf + state.m, logi)
    i_s = jnp.exp(logi - m_new).astype(z.dtype)
    f_s = jnp.exp(logf + state.m - m_new).astype(z.dtype)
    c = f_s * state.c + i_s * z
    n = f_s * state.n + i_s
    h = o * c / jnp.maximum(jnp.abs(n), 1.0)
    return h, SLSTMState(c=c, n=n, h=h, m=m_new)


def slstm_seq(p, x: jax.Array, cfg: XLSTMCfg,
              state: Optional[SLSTMState] = None
              ) -> Tuple[jax.Array, SLSTMState]:
    """Sequential sLSTM over (B, S, d); returns head outputs (B, S, d)."""
    B, S, d = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim_s
    xp = (x @ p["w_in"]["w"].astype(x.dtype)).reshape(B, S, 4, nh, hd)
    if state is None:
        state = slstm_state_init(cfg, B, x.dtype)

    def step(st, xt):
        h, st2 = _slstm_cell(p, xt, st, cfg)
        return st2, h

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(xp, 1, 0))
    return jnp.moveaxis(hs, 0, 1).reshape(B, S, d), state


def slstm_state_init(cfg: XLSTMCfg, batch: int, dtype=jnp.float32) -> SLSTMState:
    nh, hd = cfg.n_heads, cfg.head_dim_s
    z = jnp.zeros((batch, nh, hd), dtype)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((batch, nh, hd), -1e30,
                                                jnp.float32))


def slstm_block_ffn(p, y: jax.Array) -> jax.Array:
    """Post-cell part of the sLSTM block: group-norm + gated FFN."""
    y = layernorm(p["gn"], y)
    h = jax.nn.gelu(y @ p["ffn_gate"]["w"].astype(y.dtype)) \
        * (y @ p["ffn_up"]["w"].astype(y.dtype))
    return h @ p["ffn_down"]["w"].astype(y.dtype)


def slstm_block(p, x: jax.Array, cfg: XLSTMCfg) -> jax.Array:
    """sLSTM block body: cell scan + group-norm + gated FFN."""
    y, _ = slstm_seq(p, x, cfg)
    return slstm_block_ffn(p, y)


def slstm_decode_step(p, x_t: jax.Array, state: SLSTMState, cfg: XLSTMCfg
                      ) -> Tuple[jax.Array, SLSTMState]:
    B, d = x_t.shape
    nh, hd = cfg.n_heads, cfg.head_dim_s
    xp = (x_t @ p["w_in"]["w"].astype(x_t.dtype)).reshape(B, 4, nh, hd)
    h, state = _slstm_cell(p, xp, state, cfg)
    return slstm_block_ffn(p, h.reshape(B, d)), state


def mlstm_flops(tokens: int, seq: int, cfg: XLSTMCfg) -> float:
    d, di, nh, hd = cfg.d_model, cfg.d_inner_m, cfg.n_heads, cfg.head_dim_m
    proj = 2.0 * tokens * d * 2 * di \
        + 2.0 * tokens * (3 * nh * hd * hd + di * 2 * nh) \
        + 2.0 * tokens * di * d
    quad = 2.0 * 2.0 * tokens * seq * nh * hd
    return proj + quad


def slstm_flops(tokens: int, cfg: XLSTMCfg) -> float:
    d, nh, hd = cfg.d_model, cfg.n_heads, cfg.head_dim_s
    d_ff = _slstm_ffn_width(cfg)
    cell = 2.0 * tokens * d * 4 * d + 2.0 * tokens * 4 * nh * hd * hd
    ffn = 2.0 * tokens * d * d_ff * 3
    return cell + ffn
