"""Mixture-of-Experts layers (Mixtral-style top-2, DeepSeekMoE fine-grained
top-6 with shared experts).

Three execution paths, all numerically equivalent when capacity is
sufficient (tested against each other):

* ``moe_loop``     — reference: loop over experts with masking (oracle for
                     tests; FLOPs scale with E, never used at scale);
* ``moe_ragged``   — sort tokens by expert, one ``jax.lax.ragged_dot`` per
                     projection (exact active-token FLOPs; default on a
                     single device);
* ``moe_capacity`` — static (E, C, d) dispatch buffers built by sort +
                     scatter, batched einsum over experts (the GSPMD path:
                     expert dim shards over the ``model``/``expert`` mesh
                     axis, scatters/gathers lower to all-to-all).  Tokens
                     beyond capacity are dropped (standard; capacity_factor
                     controls the trade).

Router: softmax over expert logits, top-k, renormalized gates, plus the
standard load-balance auxiliary loss (fraction·probability product).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from .layers import dense_init


class MoEOut(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array


def moe_init(rng: jax.Array, d: int, d_ff: int, n_experts: int,
             n_shared: int = 0, shared_d_ff: Optional[int] = None,
             dtype=jnp.float32) -> Dict[str, Any]:
    ks = jax.random.split(rng, 5)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(d_ff)

    def ew(key, shape, scale):
        return (jax.random.normal(key, shape) * scale).astype(dtype)

    p = {"router": dense_init(ks[0], d, n_experts, dtype),
         "gate": ew(ks[1], (n_experts, d, d_ff), s_in),
         "up": ew(ks[2], (n_experts, d, d_ff), s_in),
         "down": ew(ks[3], (n_experts, d_ff, d), s_out)}
    if n_shared > 0:
        sdf = shared_d_ff if shared_d_ff is not None else n_shared * d_ff
        kg, ku, kd = jax.random.split(ks[4], 3)
        p["shared"] = {"gate": dense_init(kg, d, sdf, dtype),
                       "up": dense_init(ku, d, sdf, dtype),
                       "down": dense_init(kd, sdf, d, dtype, scale=s_out)}
    return p


def route(p_router, x2d: jax.Array, top_k: int
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x2d: (T, d) -> (gates (T,K), expert_idx (T,K), aux_loss)."""
    logits = (x2d @ p_router["w"].astype(x2d.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)             # (T, E)
    gates, idx = jax.lax.top_k(probs, top_k)            # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    E = logits.shape[-1]
    # load-balance aux: E * sum_e (mean prob_e) * (fraction routed to e)
    frac = jnp.mean(jnp.sum(jax.nn.one_hot(idx, E), axis=1), axis=0)  # (E,)
    mean_p = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * mean_p)
    return gates.astype(x2d.dtype), idx, aux


def _expert_ffn(xe: jax.Array, gate_w: jax.Array, up_w: jax.Array,
                down_w: jax.Array) -> jax.Array:
    h = jax.nn.silu(xe @ gate_w) * (xe @ up_w)
    return h @ down_w


def moe_loop(p, x: jax.Array, top_k: int) -> MoEOut:
    """Oracle: every expert on every token, masked combine."""
    B, S, d = x.shape
    x2 = x.reshape(-1, d)
    gates, idx, aux = route(p["router"], x2, top_k)
    E = p["gate"].shape[0]
    y = jnp.zeros_like(x2)
    for e in range(E):
        ye = _expert_ffn(x2, p["gate"][e].astype(x.dtype),
                         p["up"][e].astype(x.dtype),
                         p["down"][e].astype(x.dtype))
        w_e = jnp.sum(jnp.where(idx == e, gates, 0.0), axis=-1)   # (T,)
        y = y + ye * w_e[:, None]
    y = y + _shared(p, x2)
    return MoEOut(y.reshape(B, S, d), aux)


def _sort_by_expert(idx: jax.Array, top_k: int):
    """Flatten (T,K) assignments, stable-sort by expert id."""
    flat_e = idx.reshape(-1)                               # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    token_of = order // top_k
    return flat_e, order, token_of


def moe_ragged(p, x: jax.Array, top_k: int) -> MoEOut:
    """Exact top-k MoE via ragged_dot (tokens grouped by expert)."""
    B, S, d = x.shape
    x2 = x.reshape(-1, d)
    gates, idx, aux = route(p["router"], x2, top_k)
    E = p["gate"].shape[0]
    flat_e, order, token_of = _sort_by_expert(idx, top_k)
    xs = x2[token_of]                                       # (T*K, d) sorted
    group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)
    h = (jax.nn.silu(jax.lax.ragged_dot(xs, p["gate"].astype(x.dtype), group_sizes))
         * jax.lax.ragged_dot(xs, p["up"].astype(x.dtype), group_sizes))
    ys = jax.lax.ragged_dot(h, p["down"].astype(x.dtype), group_sizes)  # (T*K, d)
    gflat = gates.reshape(-1)[order]
    contrib = ys * gflat[:, None]
    y = jnp.zeros_like(x2).at[token_of].add(contrib)
    y = y + _shared(p, x2)
    return MoEOut(y.reshape(B, S, d), aux)


def moe_capacity(p, x: jax.Array, top_k: int,
                 capacity_factor: float = 1.25,
                 capacity: Optional[int] = None) -> MoEOut:
    """Static-capacity dispatch (GSPMD path).

    Buffers: (E, C, d).  Position of each (token, choice) within its expert
    comes from a stable sort; entries with position >= C are dropped (their
    gate mass is simply lost, as in Switch/GShard).
    """
    B, S, d = x.shape
    T = B * S
    x2 = x.reshape(T, d)
    gates, idx, aux = route(p["router"], x2, top_k)
    E = p["gate"].shape[0]
    C = capacity if capacity is not None else max(
        1, int(math.ceil(T * top_k / E * capacity_factor)))

    flat_e, order, token_of = _sort_by_expert(idx, top_k)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * top_k) - starts[sorted_e]          # pos within expert
    keep = pos < C
    pos_c = jnp.where(keep, pos, 0)

    # dispatch: (E, C, d); dropped slots receive zeros.  The
    # "expert_dispatch" rule (OFF in baseline) shards the buffers over the
    # model axis -> expert parallelism: the scatter lowers to an all-to-all
    # and each shard runs only its local experts' matmuls (§Perf lever).
    buf = jnp.zeros((E, C, d), dtype=x.dtype)
    vals = jnp.where(keep[:, None], x2[token_of], 0.0)
    buf = buf.at[sorted_e, pos_c].add(vals)
    buf = constrain(buf, ("expert_dispatch", None, None))

    h = jnp.einsum("ecd,edf->ecf", buf, p["gate"].astype(x.dtype))
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["up"].astype(x.dtype))
    out = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(x.dtype))
    out = constrain(out, ("expert_dispatch", None, None))

    # combine: gather each kept (token, choice)'s result, weight by gate
    gflat = gates.reshape(-1)[order]
    got = out[sorted_e, pos_c] * jnp.where(keep, gflat, 0.0)[:, None]
    y = jnp.zeros_like(x2).at[token_of].add(got)
    y = y + _shared(p, x2)
    return MoEOut(y.reshape(B, S, d), aux)


def _shared(p, x2: jax.Array) -> jax.Array:
    if "shared" not in p:
        return jnp.zeros_like(x2)
    sp = p["shared"]
    h = jax.nn.silu(x2 @ sp["gate"]["w"].astype(x2.dtype)) \
        * (x2 @ sp["up"]["w"].astype(x2.dtype))
    return h @ sp["down"]["w"].astype(x2.dtype)


def moe_apply(p, x: jax.Array, top_k: int, impl: str = "ragged",
              capacity_factor: float = 1.25) -> MoEOut:
    if impl == "loop":
        return moe_loop(p, x, top_k)
    if impl == "ragged":
        return moe_ragged(p, x, top_k)
    if impl == "capacity":
        return moe_capacity(p, x, top_k, capacity_factor)
    raise ValueError(f"unknown moe impl {impl!r}")


def moe_flops(tokens: int, d: int, d_ff: int, top_k: int,
              n_shared_ff: int = 0) -> float:
    """Active forward FLOPs (router negligible, counted anyway)."""
    routed = 2.0 * tokens * top_k * d * d_ff * 3
    shared = 2.0 * tokens * d * n_shared_ff * 3
    return routed + shared
