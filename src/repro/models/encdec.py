"""Encoder-decoder seq2seq LM (seamless-m4t backbone).

The modality frontend (mel-spectrogram + conv codec) is a stub per the
assignment carve-out: ``src_embeds`` arrives as precomputed frame embeddings
(B, S_src, d_frontend), projected into d_model.  The transformer backbone —
bidirectional encoder, causal decoder with cross-attention — is fully
implemented.

``n_layers`` in the assigned config counts encoder+decoder
(n_enc = n_dec = n_layers / 2, DESIGN.md §5).  Decode keeps two caches: the
decoder self-attention KV cache (grows with generated tokens) and the fixed
cross-attention KV computed once from the encoder memory.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.distributed.sharding import constrain
from . import attention as attn
from .scan_config import scan as _scan
from .layers import (cross_entropy, dense, dense_init, embed, embed_init,
                     mlp, mlp_init, norm_apply, norm_init)


def _enc_block_init(rng, cfg: ModelCfg):
    k1, k2 = jax.random.split(rng)
    return {"ln1": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
            "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim,
                                   cfg.param_dtype),
            "ln2": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act,
                            cfg.param_dtype)}


def _dec_block_init(rng, cfg: ModelCfg):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {"ln1": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
            "self_attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.head_dim,
                                        cfg.param_dtype),
            "ln_x": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
            "cross_attn": attn.attn_init(k2, cfg.d_model, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.head_dim,
                                         cfg.param_dtype),
            "ln2": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
            "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.act,
                            cfg.param_dtype)}


def init(cfg: ModelCfg, rng: jax.Array) -> Dict[str, Any]:
    ks = jax.random.split(rng, 6)
    n_enc, n_dec = cfg.n_enc_layers, cfg.n_dec_layers
    return {
        "src_proj": dense_init(ks[0], cfg.d_frontend, cfg.d_model,
                               cfg.param_dtype),
        "embed": embed_init(ks[1], cfg.vocab_padded, cfg.d_model,
                             cfg.param_dtype),
        "enc": jax.vmap(lambda r: _enc_block_init(r, cfg))(
            jax.random.split(ks[2], n_enc)),
        "enc_norm": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
        "dec": jax.vmap(lambda r: _dec_block_init(r, cfg))(
            jax.random.split(ks[3], n_dec)),
        "final_norm": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
        "head": dense_init(ks[4], cfg.d_model, cfg.vocab_padded,
                           cfg.param_dtype, scale=0.02),
    }


def _kw(cfg: ModelCfg, rope: bool = True):
    return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.head_dim, window=None,
                rope_fraction=cfg.rope_fraction if rope else 0.0,
                rope_theta=cfg.rope_theta)


def encode(cfg: ModelCfg, params, src_embeds: jax.Array) -> jax.Array:
    """src_embeds: (B, S_src, d_frontend) -> encoder memory (B, S_src, d)."""
    x = dense(params["src_proj"], src_embeds.astype(cfg.dtype))
    x = constrain(x, ("batch", None, None))

    def body(h, pl):
        hh = norm_apply(cfg.norm, pl["ln1"], h)
        h = h + attn.attn_train(pl["attn"], hh, causal=False, **_kw(cfg))
        hh = norm_apply(cfg.norm, pl["ln2"], h)
        h = h + mlp(pl["mlp"], hh, cfg.act)
        return constrain(h, ("batch", "act_seq", None)), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = _scan(body, x, params["enc"])
    return norm_apply(cfg.norm, params["enc_norm"], x)


def _decoder_train(cfg: ModelCfg, params, tgt_tokens, memory):
    x = embed(params["embed"], tgt_tokens, cfg.dtype)
    x = constrain(x, ("batch", None, None))

    def body(h, pl):
        hh = norm_apply(cfg.norm, pl["ln1"], h)
        h = h + attn.attn_train(pl["self_attn"], hh, causal=True, **_kw(cfg))
        hh = norm_apply(cfg.norm, pl["ln_x"], h)
        h = h + attn.attn_train(pl["cross_attn"], hh, causal=False,
                                x_kv=memory, **_kw(cfg, rope=False))
        hh = norm_apply(cfg.norm, pl["ln2"], h)
        h = h + mlp(pl["mlp"], hh, cfg.act)
        return constrain(h, ("batch", "act_seq", None)), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = _scan(body, x, params["dec"])
    x = norm_apply(cfg.norm, params["final_norm"], x)
    return _head(cfg, params, x)


def _head(cfg: ModelCfg, params, x):
    logits = dense(params["head"], x)
    if cfg.vocab_padded != cfg.vocab:
        valid = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(valid, logits, -1e30)
    return logits


def train_loss(cfg: ModelCfg, params, batch: Dict[str, jax.Array]
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: src_embeds (B,S_src,d_fe), tokens (B,S_tgt), labels."""
    memory = encode(cfg, params, batch["src_embeds"])
    logits = _decoder_train(cfg, params, batch["tokens"], memory)
    ce = cross_entropy(logits, batch["labels"])
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------- serving --
def _cross_kv(cfg, pl, memory):
    B, Sk = memory.shape[:2]
    k = dense({"w": pl["cross_attn"]["wk"]["w"]}, memory)
    v = dense({"w": pl["cross_attn"]["wv"]["w"]}, memory)
    return (k.reshape(B, Sk, cfg.n_kv_heads, cfg.head_dim),
            v.reshape(B, Sk, cfg.n_kv_heads, cfg.head_dim))


def prefill(cfg: ModelCfg, params, src_embeds: jax.Array,
            tgt_tokens: jax.Array, cache_len: int
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Encode source + run the decoder over a target prefix, building caches."""
    memory = encode(cfg, params, src_embeds)
    x = embed(params["embed"], tgt_tokens, cfg.dtype)
    S = tgt_tokens.shape[1]

    def body(h, pl):
        hh = norm_apply(cfg.norm, pl["ln1"], h)
        a, kvc = attn.attn_prefill(pl["self_attn"], hh, cache_len=cache_len,
                                   **_kw(cfg))
        h = h + a
        hh = norm_apply(cfg.norm, pl["ln_x"], h)
        h = h + attn.attn_train(pl["cross_attn"], hh, causal=False,
                                x_kv=memory, **_kw(cfg, rope=False))
        hh = norm_apply(cfg.norm, pl["ln2"], h)
        h = h + mlp(pl["mlp"], hh, cfg.act)
        ck, cv = _cross_kv(cfg, pl, memory)
        return h, {"k": kvc.k, "v": kvc.v, "xk": ck, "xv": cv}

    x, caches = _scan(body, x, params["dec"])
    x = norm_apply(cfg.norm, params["final_norm"], x[:, -1:, :])
    logits = _head(cfg, params, x)
    return logits, {"pos": jnp.asarray(S, jnp.int32), "dec": caches}


def cache_init(cfg: ModelCfg, batch: int, cache_len: int, src_len: int,
               dtype=None) -> Dict[str, Any]:
    dtype = dtype or cfg.dtype
    n_dec = cfg.n_dec_layers

    def z(*shape):
        return jnp.zeros((n_dec,) + shape, dtype)

    return {"pos": jnp.zeros((), jnp.int32),
            "dec": {"k": z(batch, cache_len, cfg.n_kv_heads, cfg.head_dim),
                    "v": z(batch, cache_len, cfg.n_kv_heads, cfg.head_dim),
                    "xk": z(batch, src_len, cfg.n_kv_heads, cfg.head_dim),
                    "xv": z(batch, src_len, cfg.n_kv_heads, cfg.head_dim)}}


def decode_step(cfg: ModelCfg, params, cache: Dict[str, Any],
                tokens: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decoder token against (self KV cache, fixed cross KV)."""
    pos = cache["pos"]
    x = embed(params["embed"], tokens, cfg.dtype)
    x = constrain(x, ("batch", None, None))

    def body(h, xs):
        pl, c = xs
        hh = norm_apply(cfg.norm, pl["ln1"], h)
        a, kvc = attn.attn_decode(pl["self_attn"], hh,
                                  attn.KVCache(c["k"], c["v"]), pos,
                                  **_kw(cfg))
        h = h + a
        hh = norm_apply(cfg.norm, pl["ln_x"], h)
        # cross-attention against fixed memory KV (no mask, no rope)
        q = dense({"w": pl["cross_attn"]["wq"]["w"]}, hh)
        B = q.shape[0]
        q = q.reshape(B, 1, cfg.n_heads, cfg.head_dim)
        o = attn.sdpa(q, c["xk"].astype(q.dtype), c["xv"].astype(q.dtype),
                      None)
        o = dense({"w": pl["cross_attn"]["wo"]["w"]},
                  o.reshape(B, 1, cfg.n_heads * cfg.head_dim))
        h = h + o
        hh = norm_apply(cfg.norm, pl["ln2"], h)
        h = h + mlp(pl["mlp"], hh, cfg.act)
        return h, {"k": kvc.k, "v": kvc.v, "xk": c["xk"], "xv": c["xv"]}

    x, dec = _scan(body, x, (params["dec"], cache["dec"]))
    x = norm_apply(cfg.norm, params["final_norm"], x)
    logits = _head(cfg, params, x)
    return logits, {"pos": pos + 1, "dec": dec}


def count_params(cfg: ModelCfg) -> int:
    d, V = cfg.d_model, cfg.vocab_padded
    nrm = 2 * d if cfg.norm == "layernorm" else d
    attn_p = d * cfg.n_heads * cfg.head_dim * 2 \
        + d * cfg.n_kv_heads * cfg.head_dim * 2
    mlp_mults = 3 if cfg.act in ("silu", "swiglu") else 2
    mlp_p = d * cfg.d_ff * mlp_mults
    enc = cfg.n_enc_layers * (attn_p + mlp_p + 2 * nrm)
    dec = cfg.n_dec_layers * (2 * attn_p + mlp_p + 3 * nrm)
    return int(cfg.d_frontend * d + V * d + enc + nrm + dec + nrm + d * V)
