"""OpGraph builders: models expressed in the FusionLLM OP-DAG IR.

These feed the decentralized runtime (scheduler → RAD executor → simulator):
* :func:`gpt_opgraph` — decoder-only transformer, one OP node per block
  (the paper's GPT-2 workload; Fig. 7 shows exactly this style of per-layer
  model registration);
* :func:`convnet_opgraph` — small CNN classifier (stand-in for the paper's
  ResNet-18/101 CV workloads);
* :func:`profile_opgraph` — metadata-only transformer graph (flops/bytes
  per op, no apply functions) at any scale — e.g. the full GPT2-XL — for
  the latency simulator, which never executes compute.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelCfg
from repro.core.opgraph import OpGraph, OpNode, OpType
from .attention import attn_flops
from .causal_lm import _dense_block, _dense_block_init
from .layers import (cross_entropy, dense, dense_init, embed, embed_init,
                     mlp_flops, norm_apply, norm_init)


def gpt_opgraph(cfg: ModelCfg, batch: int, seq: int) -> OpGraph:
    """Executable OP-DAG: tokens -> embed -> block_0..L-1 -> head -> loss."""
    g = OpGraph(f"{cfg.name}-opdag")
    g.add(OpNode("tokens", OpType.PLACEHOLDER))
    g.add(OpNode("labels", OpType.PLACEHOLDER))
    d, V = cfg.d_model, cfg.vocab_padded

    def embed_init_fn(rng, tok_shape):
        k1, k2 = jax.random.split(rng)
        p = {"tok": embed_init(k1, V, d, cfg.param_dtype)}
        if cfg.rope_fraction == 0.0:
            p["pos"] = embed_init(k2, cfg.max_seq, d, cfg.param_dtype)
        return p

    def embed_apply(p, tokens):
        x = embed(p["tok"], tokens, cfg.dtype)
        if "pos" in p:
            x = x + embed(p["pos"], jnp.arange(tokens.shape[1]),
                          cfg.dtype)[None]
        return x

    g.add(OpNode("embed", OpType.PARAMETRIC, args=("tokens",),
                 init_fn=embed_init_fn, apply_fn=embed_apply,
                 out_shape_fn=lambda s: (s[0], s[1], d),
                 flops_fn=lambda s: 0.0,
                 n_params_fn=lambda s: V * d + (cfg.max_seq * d
                                                if cfg.rope_fraction == 0.0
                                                else 0)))
    prev = "embed"
    blk_flops = (attn_flops(batch * seq, seq, d, cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim)
                 + mlp_flops(batch * seq, d, cfg.d_ff, cfg.act))
    blk_params = None
    for i in range(cfg.n_layers):
        name = f"block_{i}"
        g.add(OpNode(
            name, OpType.PARAMETRIC, args=(prev,),
            init_fn=lambda rng, s: _dense_block_init(rng, cfg),
            apply_fn=lambda p, x: _dense_block(cfg, p, x, cfg.window),
            out_shape_fn=lambda s: s,
            flops_fn=lambda s, f=blk_flops: f,
            n_params_fn=lambda s: _count_block_params(cfg)))
        prev = name

    def head_init(rng, s):
        return {"ln": norm_init(cfg.norm, d, cfg.param_dtype),
                "w": dense_init(rng, d, V, cfg.param_dtype, scale=0.02)}

    g.add(OpNode("head", OpType.PARAMETRIC, args=(prev,),
                 init_fn=head_init,
                 apply_fn=lambda p, x: dense(
                     {"w": p["w"]["w"]}, norm_apply(cfg.norm, p["ln"], x)),
                 out_shape_fn=lambda s: (s[0], s[1], V),
                 flops_fn=lambda s: 2.0 * s[0] * s[1] * d * V,
                 n_params_fn=lambda s: d * V
                 + (2 * d if cfg.norm == "layernorm" else d)))
    g.add(OpNode("loss", OpType.LOSS, args=("head", "labels"),
                 apply_fn=lambda p, logits, y: cross_entropy(logits, y),
                 out_shape_fn=lambda a, b: (),
                 flops_fn=lambda a, b: float(np.prod(a))))
    return g


def _count_block_params(cfg: ModelCfg) -> int:
    d = cfg.d_model
    nrm = 2 * d if cfg.norm == "layernorm" else d
    attn_p = d * cfg.n_heads * cfg.head_dim * 2 \
        + d * cfg.n_kv_heads * cfg.head_dim * 2
    mults = 3 if cfg.act in ("silu", "swiglu") else 2
    return attn_p + d * cfg.d_ff * mults + 2 * nrm


def convnet_opgraph(hw: int = 16, channels: int = 3, n_classes: int = 10,
                    widths=(16, 32, 64), dtype=jnp.float32) -> OpGraph:
    """Small CNN classifier as an OP-DAG (CV stand-in for ResNet)."""
    g = OpGraph("convnet-opdag")
    g.add(OpNode("images", OpType.PLACEHOLDER))
    g.add(OpNode("labels", OpType.PLACEHOLDER))
    prev, c_in, cur_hw = "images", channels, hw
    for i, c_out in enumerate(widths):
        name = f"conv_{i}"

        def init_fn(rng, s, ci=c_in, co=c_out):
            return {"w": (jax.random.normal(rng, (3, 3, ci, co))
                          * (1.0 / math.sqrt(9 * ci))).astype(dtype),
                    "b": jnp.zeros((co,), dtype)}

        def apply_fn(p, x):
            y = jax.lax.conv_general_dilated(
                x, p["w"], window_strides=(2, 2), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            return jax.nn.relu(y + p["b"])

        out_hw = -(-cur_hw // 2)
        g.add(OpNode(name, OpType.PARAMETRIC, args=(prev,),
                     init_fn=init_fn, apply_fn=apply_fn,
                     out_shape_fn=lambda s, oh=out_hw, co=c_out:
                         (s[0], oh, oh, co),
                     flops_fn=lambda s, ci=c_in, co=c_out, oh=out_hw:
                         2.0 * s[0] * oh * oh * 9 * ci * co,
                     n_params_fn=lambda s, ci=c_in, co=c_out:
                         9 * ci * co + co))
        prev, c_in, cur_hw = name, c_out, out_hw
    g.add(OpNode("pool", OpType.NON_PARAMETRIC, args=(prev,),
                 apply_fn=lambda p, x: jnp.mean(x, axis=(1, 2)),
                 out_shape_fn=lambda s: (s[0], s[3]),
                 flops_fn=lambda s: float(np.prod(s))))
    g.add(OpNode("fc", OpType.PARAMETRIC, args=("pool",),
                 init_fn=lambda rng, s: dense_init(rng, widths[-1], n_classes,
                                                   dtype),
                 apply_fn=lambda p, x: dense(p, x),
                 out_shape_fn=lambda s: (s[0], n_classes),
                 flops_fn=lambda s: 2.0 * s[0] * widths[-1] * n_classes,
                 n_params_fn=lambda s: widths[-1] * n_classes))
    g.add(OpNode("loss", OpType.LOSS, args=("fc", "labels"),
                 apply_fn=lambda p, logits, y: cross_entropy(logits, y),
                 out_shape_fn=lambda a, b: ()))
    return g


def profile_opgraph(cfg: ModelCfg, batch: int, seq: int) -> OpGraph:
    """Metadata-only graph (no apply fns) for the latency simulator —
    builds the FULL-size model's cost profile without allocating it."""
    g = OpGraph(f"{cfg.name}-profile")
    g.add(OpNode("tokens", OpType.PLACEHOLDER))
    g.add(OpNode("labels", OpType.PLACEHOLDER))
    d = cfg.d_model
    g.add(OpNode("embed", OpType.PARAMETRIC, args=("tokens",),
                 out_shape_fn=lambda s: (s[0], s[1], d),
                 flops_fn=lambda s: 0.0,
                 n_params_fn=lambda s: cfg.vocab_padded * d))
    prev = "embed"
    for i in range(cfg.n_layers):
        name = f"block_{i}"
        g.add(OpNode(name, OpType.PARAMETRIC, args=(prev,),
                     out_shape_fn=lambda s: s,
                     flops_fn=lambda s: (
                         attn_flops(s[0] * s[1], s[1], d, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim)
                         + mlp_flops(s[0] * s[1], d, cfg.d_ff, cfg.act)),
                     n_params_fn=lambda s: _count_block_params(cfg)))
        prev = name
    g.add(OpNode("head", OpType.PARAMETRIC, args=(prev,),
                 out_shape_fn=lambda s: (s[0], s[1], cfg.vocab_padded),
                 flops_fn=lambda s: 2.0 * s[0] * s[1] * d * cfg.vocab_padded,
                 n_params_fn=lambda s: d * cfg.vocab_padded))
    g.add(OpNode("loss", OpType.LOSS, args=("head", "labels"),
                 out_shape_fn=lambda a, b: (),
                 flops_fn=lambda a, b: float(np.prod(a))))
    return g
