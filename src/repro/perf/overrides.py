"""Named perf-iteration override sets (§Perf hillclimbing).

Each entry bundles the knobs one hypothesis changes — parameter-sharding
overrides, activation rules — so a dry-run can be re-lowered with
``--overrides <name>`` and diffed against the baseline record.  The log of
hypothesis → change → before/after lives in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from jax.sharding import PartitionSpec as P

_SETS: Dict[str, Dict[str, Any]] = {}


def register(name: str, **kw) -> None:
    _SETS[name] = kw


def get(name: Optional[str]) -> Optional[Dict[str, Any]]:
    if name is None:
        return None
    return _SETS[name]


def names():
    return sorted(_SETS)


# ---------------------------------------------------------------- H-sets ---
# §Perf iteration knobs (EXPERIMENTS.md logs hypothesis + before/after).

# H-seqpar: Megatron sequence parallelism — residual-stream activations
# sharded over 'model' between blocks; row-matmul psums become
# reduce-scatter + all-gather of S-sharded bf16 tensors.
register("seqpar", rules={"act_seq": "model"})

# H-ep: expert-parallel MoE dispatch — (E, C, d) buffers sharded over the
# model axis; the scatter lowers to all-to-all and each shard computes only
# its local experts.
register("ep", rules={"expert_dispatch": "model"})

# H-ep+seqpar combined.
register("ep_seqpar", rules={"expert_dispatch": "model",
                             "act_seq": "model"})

# H-moe-w: stop FSDP-sharding the expert weights' CONTRACTION dims.  The
# baseline's generic rule shards gate/up on d@data and down on f@data; the
# (E,C,·) dispatch buffers have those dims unsharded, so every expert matmul
# psums an (E,C,f)-sized partial over the data axis — measured 5.3 + 3.8 GiB
# of all-reduce per deepseek layer.  Replicating the contraction dim trades
# that for weight-sized gathers (~370 MB/layer, 14-25x cheaper).
register("moe_w", param_overrides={
    r".*moe/(?:gate|up)": P(
        None, None, None, "model"),
    r".*moe/down": P(
        None, None, "model", None),
})

# H-seqpar-dots: after seqpar flips llama3 train to memory-bound, trade the
# remat recompute traffic for saved matmul outputs (footprint headroom:
# 12.7 GiB of 16 GiB).
register("seqpar_dots", rules={"act_seq": "model"},
         cfg={"remat_policy": "dots"})

# H-moe-ragged: replace the capacity-dispatch einsum path with
# sort + ragged_dot (exact active-token FLOPs; different GSPMD lowering).
register("moe_ragged", cfg={"moe_impl": "ragged"})

# H-moe-w + sequence parallelism on the attention side.
register("moe_w_seqpar", rules={"act_seq": "model"}, param_overrides={
    r".*moe/(?:gate|up)": P(
        None, None, None, "model"),
    r".*moe/down": P(
        None, None, "model", None),
})
