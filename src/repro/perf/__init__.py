from . import overrides
